//! Host-side integration tests for the pipelined orchestrator: determinism
//! of per-step planning, the engine driving trainer-shaped state, and
//! resumable checkpoints — none of which need PJRT or artifacts.

use std::path::Path;
use std::sync::Mutex;

use nat_rl::config::RunConfig;
use nat_rl::coordinator::pipeline::engine::{self, PipelineOpts};
use nat_rl::coordinator::trainer::{mask_rng, plan_step};
use nat_rl::model::Manifest;
use nat_rl::runtime::{Checkpoint, OptState, ParamStore, TrainMeta};
use nat_rl::util::json::Json;

/// Per-step plans must be pure functions of (seed, step): identical across
/// calls, across processes, and independent of which steps were planned
/// before — the property that lets rollout workers plan any future step.
#[test]
fn step_plans_are_pure_functions_of_seed_and_step() {
    let cfg = RunConfig::default();
    for step in [0u64, 1, 7, 1000] {
        let mut a = plan_step(&cfg, step);
        let mut b = plan_step(&cfg, step);
        assert_eq!(
            a.tasks.iter().map(|t| t.prompt.clone()).collect::<Vec<_>>(),
            b.tasks.iter().map(|t| t.prompt.clone()).collect::<Vec<_>>(),
        );
        for _ in 0..16 {
            assert_eq!(a.rng_rollout.next_u64(), b.rng_rollout.next_u64());
            assert_eq!(a.rng_mask.next_u64(), b.rng_mask.next_u64());
        }
        // mask_rng must be the exact stream the plan embeds (the pipelined
        // learner re-derives it without the plan).
        let mut c = plan_step(&cfg, step);
        let mut m = mask_rng(&cfg, step);
        assert_eq!(c.rng_mask.next_u64(), m.next_u64());
    }
    // Different steps and different seeds give different streams/tasks.
    let mut p0 = plan_step(&cfg, 0);
    let mut p1 = plan_step(&cfg, 1);
    assert_ne!(p0.rng_rollout.next_u64(), p1.rng_rollout.next_u64());
    let mut other = RunConfig::default();
    other.seed = 1;
    let mut q0 = plan_step(&other, 0);
    assert_ne!(plan_step(&cfg, 0).rng_rollout.next_u64(), q0.rng_rollout.next_u64());
}

/// Drive the engine with trainer-shaped state (a real `ParamStore` as the
/// published snapshot): the synchronous single-worker schedule must produce
/// bit-identical parameters to the serial loop, because each "rollout"
/// observes exactly the previous "apply"'s output.
#[test]
fn engine_with_paramstore_snapshots_matches_serial_bitwise() {
    let n_params = 64usize;
    let steps = 12u64;
    // Deterministic fake stages: "rollout" hashes the snapshot into a
    // pseudo-group; "learn" folds the group into every parameter.
    let fake_rollout = |step: u64, params: &ParamStore| -> f32 {
        let s: f32 = params.flat.iter().sum();
        (s * 0.25 + step as f32).sin()
    };
    let fake_apply = |params: &mut ParamStore, g: f32| {
        for (i, p) in params.flat.iter_mut().enumerate() {
            *p = (*p + g * (i as f32 + 1.0).recip()) * 0.999;
        }
    };

    // Serial reference.
    let mut serial = ParamStore { flat: vec![0.01; n_params] };
    for k in 0..steps {
        let g = fake_rollout(k, &serial);
        fake_apply(&mut serial, g);
    }

    // Pipelined, workers=1, staleness=0.
    let mut piped = ParamStore { flat: vec![0.01; n_params] };
    let trace = Mutex::new(Vec::new());
    engine::run(
        &PipelineOpts { workers: 1, queue_depth: 2, max_staleness: 0 },
        0,
        steps,
        piped.clone(),
        |k, _version, snap: &ParamStore| {
            trace.lock().unwrap().push(k);
            Ok(fake_rollout(k, snap))
        },
        |meta, g: f32| {
            assert_eq!(meta.staleness(), 0);
            fake_apply(&mut piped, g);
            Ok(piped.clone())
        },
        |_| Ok(()),
    )
    .unwrap();
    assert_eq!(piped.flat, serial.flat, "workers=1 pipeline diverged from serial");
    assert_eq!(*trace.lock().unwrap(), (0..steps).collect::<Vec<_>>());
}

/// With overlap enabled the run is NOT necessarily bit-identical, but every
/// group must respect the staleness bound and steps must apply in order.
#[test]
fn engine_with_paramstore_snapshots_bounds_staleness_under_overlap() {
    let steps = 40u64;
    let stal = 1u64;
    let mut version_log = Vec::new();
    let mut params = ParamStore { flat: vec![1.0; 8] };
    engine::run(
        &PipelineOpts { workers: 3, queue_depth: 2, max_staleness: stal },
        0,
        steps,
        params.clone(),
        |k, _version, snap: &ParamStore| Ok(snap.flat[0] + k as f32),
        |meta, _g: f32| {
            assert!(meta.staleness() <= stal);
            version_log.push((meta.step, meta.behaviour_version));
            params.flat[0] += 1.0;
            Ok(params.clone())
        },
        |_| Ok(()),
    )
    .unwrap();
    assert_eq!(version_log.len(), steps as usize);
    for (i, &(step, _)) in version_log.iter().enumerate() {
        assert_eq!(step, i as u64, "applies out of order");
    }
    assert_eq!(params.flat[0], 1.0 + steps as f32);
}

fn toy_manifest() -> Manifest {
    let j = Json::parse(
        r#"{
      "config": {"name":"t","vocab":8,"d_model":4,"n_layers":1,"n_heads":1,
        "d_ff":8,"prompt_len":4,"max_resp":8,"buckets":[4,8],
        "batch_rollout":2,"batch_train":2,"pretrain_len":12,
        "batch_pretrain":2,"lr":0.001,"clip_eps":0.2,"grad_clip":1.0,
        "pretrain_lr":0.001},
      "param_count": 40,
      "params": [
        {"name":"embed","shape":[8,4],"size":32,"offset":0},
        {"name":"head","shape":[4,2],"size":8,"offset":32}],
      "artifacts": {"generate":"g.txt","apply":"a.txt","pretrain":"p.txt",
        "grad":{"4":"g4.txt","8":"g8.txt"},"score":{"8":"s8.txt"}}
    }"#,
    )
    .unwrap();
    Manifest::from_json(Path::new("/tmp"), &j).unwrap()
}

/// Mid-run checkpoints round-trip the complete training state through the
/// public API: params, both Adam moments, optimizer step, trainer step and
/// run seed — everything a `--resume` needs for an exact continuation.
#[test]
fn mid_run_checkpoint_roundtrips_full_training_state() {
    let m = toy_manifest();
    let dir = std::env::temp_dir().join("nat_rl_pipeline_ckpt_test");
    let path = dir.join("mid.bin");

    let mut params = ParamStore::zeros_like(&m);
    for (i, x) in params.flat.iter_mut().enumerate() {
        *x = (i as f32) * 0.125 - 1.0;
    }
    let mut opt = OptState::zeros(&m);
    opt.step = 34; // 17 trainer steps x 2 ppo epochs
    opt.m.flat[5] = 0.25;
    opt.v.flat[7] = 1.5;
    let meta = TrainMeta { step: 17, seed: 123, tuner: None, shards: 2 };

    Checkpoint::save_train(&path, &m, &params, &opt, &meta).unwrap();
    let (p2, o2, t2) = Checkpoint::load_full(&path, &m).unwrap();
    let o2 = o2.expect("resumable checkpoint must carry optimizer state");
    assert_eq!(p2.flat, params.flat);
    assert_eq!(o2.step, 34);
    assert_eq!(o2.m.flat, opt.m.flat);
    assert_eq!(o2.v.flat, opt.v.flat);
    assert_eq!(t2, Some(meta));

    // Legacy checkpoints (no train state) still load through load_full.
    let legacy = dir.join("legacy.bin");
    Checkpoint::save(&legacy, &m, &params, None).unwrap();
    let (_, o3, t3) = Checkpoint::load_full(&legacy, &m).unwrap();
    assert!(o3.is_none());
    assert!(t3.is_none());
    let _ = std::fs::remove_dir_all(dir);
}

/// The pipeline config surfaces through the same dotted-override path the
/// CLI uses, and the `--resume`-adjacent keys are accepted end to end.
#[test]
fn pipeline_cli_style_overrides() {
    let mut cfg = RunConfig::default();
    for (k, v) in [
        ("pipeline.workers", "2"),
        ("pipeline.queue_depth", "3"),
        ("pipeline.max_staleness", "2"),
        ("rl.ckpt_every", "5"),
        ("train.shards", "4"),
    ] {
        cfg.set(k, v).unwrap();
    }
    assert_eq!(cfg.pipeline.workers, 2);
    assert_eq!(cfg.pipeline.queue_depth, 3);
    assert_eq!(cfg.pipeline.max_staleness, 2);
    assert_eq!(cfg.rl.ckpt_every, 5);
    assert_eq!(cfg.train.shards, 4);
}
