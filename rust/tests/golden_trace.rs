//! Golden-trace regression fixture (tier-1).
//!
//! The trace logic lives in `nat_rl::golden` and is shared with the
//! `nat golden` subcommand (`--write` regenerates the fixture, `--check` is
//! the CI drift gate). This test asserts the three determinism invariants
//! on the fixture workload — replay, shards=K, pipelined-final-hash — and
//! then replays the committed fixture at `tests/golden/sim_trace_v1.txt`
//! bit-exactly. Bootstrap contract: if the fixture is absent (fresh
//! branch), the test writes it and the generated file is then committed.

use nat_rl::golden::{fixture_path, pipelined_final_hash, serial_trace};

#[test]
fn golden_trace_replays_bit_exactly() {
    let a = serial_trace(1).unwrap();
    let b = serial_trace(1).unwrap();
    assert_eq!(a, b, "3-step seed trace is not replay-deterministic");

    // The sharded learner must reproduce the identical trace (K-invariance
    // on the exact committed fixture workload)...
    let sharded = serial_trace(4).unwrap();
    assert_eq!(a, sharded, "shards=4 changed the golden trace");
    // ...and the pipelined trainer must land on the same parameters.
    let serial_final = a
        .last()
        .and_then(|l| l.split_whitespace().nth(3).map(String::from))
        .expect("trace has a final hash field");
    let piped = pipelined_final_hash(2, 1).unwrap();
    assert_eq!(
        format!("{piped:016x}"),
        serial_final,
        "pipelined trainer diverged from the serial parameters"
    );

    let rendered = a.join("\n") + "\n";
    let path = fixture_path();
    if path.exists() {
        let committed = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            committed, rendered,
            "training semantics drifted from the committed golden trace \
             ({}). If the change is intentional, rerun `nat golden --write` \
             and commit the new fixture with an explanation.",
            path.display()
        );
    } else {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &rendered).unwrap();
        eprintln!(
            "bootstrapped golden trace fixture at {} — commit this file \
             (or run `nat golden --write`)",
            path.display()
        );
    }
}
