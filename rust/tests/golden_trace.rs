//! Golden-trace regression fixture (issue satellite).
//!
//! A 3-step training trace from the seed configuration (default method
//! RPC(C=8), budget packer, bucketed rollout engine, seed 0) run on the
//! deterministic sim runtime, serialized as one canonical line per step:
//! every non-timing `StepStats` field in shortest-roundtrip decimal plus an
//! FNV-1a hash of the post-step parameter bits. The committed fixture at
//! `tests/golden/sim_trace_v1.txt` must replay bit-exactly, so any future
//! refactor that silently changes training semantics — masking streams,
//! packing, reduction order, apply math — fails tier-1 here instead of
//! shipping.
//!
//! Bootstrap contract: if the fixture file is absent (first run on a fresh
//! feature branch), the test writes it and still asserts in-process replay
//! determinism; the generated file is then committed. The sim kernels use
//! only IEEE-exact float ops (no transcendentals), so the fixture is
//! portable across hosts.

use std::path::Path;

use nat_rl::config::RunConfig;
use nat_rl::coordinator::pipeline::PipelineTrainer;
use nat_rl::coordinator::trainer::{StepStats, Trainer};
use nat_rl::runtime::sim::{init_params, sim_manifest};
use nat_rl::runtime::{OptState, Runtime};
use nat_rl::tasks::Tier;

mod common;
use common::fnv1a;

/// The seed config of the trace (kept independent of `RunConfig` default
/// drift for the documented fields: any change here invalidates the
/// fixture on purpose).
fn trace_cfg(shards: usize, workers: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "sim".into();
    cfg.seed = 0;
    cfg.rl.tiers = vec![Tier::Easy];
    cfg.rl.prompts_per_step = 2;
    cfg.rl.group_size = 4;
    cfg.train.shards = shards;
    cfg.pipeline.workers = workers;
    cfg
}

fn line(s: &StepStats, param_hash: u64) -> String {
    format!(
        "step {} hash {:016x} reward {} entropy {} clip {} kl {} gnorm {} sel {} btgt {} \
         breal {} svar {} rlen {} waste {} mem {} peak {} mb {} seqs {}",
        s.step,
        param_hash,
        s.reward_mean,
        s.entropy,
        s.clip_frac,
        s.kl,
        s.grad_norm,
        s.selected_ratio,
        s.budget_target,
        s.budget_realized,
        s.sel_var,
        s.resp_len_mean,
        s.padding_waste,
        s.mem_gb,
        s.peak_mem_gb,
        s.micro_batches,
        s.sequences
    )
}

/// Run the 3-step seed trace; `shards`/`workers` must not change a single
/// bit of it (the sharded-learner and pipelined-scheduler invariants).
fn trace(shards: usize, workers: usize) -> Vec<String> {
    let rt = Runtime::sim(sim_manifest());
    let params = init_params(&rt.manifest);
    let opt = OptState::zeros(&rt.manifest);
    if workers > 0 {
        let mut tr = PipelineTrainer::new(&rt, trace_cfg(shards, workers), params, opt);
        tr.train(3, false).unwrap();
        // Reconstruct the per-step lines from the recorder (the pipelined
        // trainer returns stats via its recorder series) — only the FINAL
        // param hash is asserted for the pipelined leg.
        vec![format!("final hash {:016x}", fnv1a(&tr.params.flat))]
    } else {
        let mut tr = Trainer::new(&rt, trace_cfg(shards, workers), params, opt);
        let mut out = Vec::new();
        for _ in 0..3 {
            let s = tr.step().unwrap();
            out.push(line(&s, fnv1a(&tr.params.flat)));
        }
        out
    }
}

#[test]
fn golden_trace_replays_bit_exactly() {
    let a = trace(1, 0);
    let b = trace(1, 0);
    assert_eq!(a, b, "3-step seed trace is not replay-deterministic");

    // The sharded learner must reproduce the identical trace (K-invariance
    // on the exact committed fixture workload)...
    let sharded = trace(4, 0);
    assert_eq!(a, sharded, "shards=4 changed the golden trace");
    // ...and the pipelined trainer must land on the same parameters.
    let piped = trace(2, 1);
    let rt = Runtime::sim(sim_manifest());
    let mut serial = Trainer::new(
        &rt,
        trace_cfg(1, 0),
        init_params(&rt.manifest),
        OptState::zeros(&rt.manifest),
    );
    serial.train(3, false).unwrap();
    let serial_final = fnv1a(&serial.params.flat);
    assert_eq!(piped, vec![format!("final hash {serial_final:016x}")]);

    let rendered = a.join("\n") + "\n";
    let path = Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/golden/sim_trace_v1.txt"
    ));
    if path.exists() {
        let committed = std::fs::read_to_string(path).unwrap();
        assert_eq!(
            committed, rendered,
            "training semantics drifted from the committed golden trace \
             ({}). If the change is intentional, delete the fixture, rerun \
             this test to regenerate it, and commit the new file with an \
             explanation.",
            path.display()
        );
    } else {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(path, &rendered).unwrap();
        eprintln!(
            "bootstrapped golden trace fixture at {} — commit this file",
            path.display()
        );
    }
}
