//! Cross-module integration tests that do NOT require PJRT or artifacts:
//! the full host-side path from task generation through rollout-shaped data
//! to packed learner micro-batches, plus estimator-level checks that tie
//! masking, advantages and the batcher together.

use nat_rl::config::Method;
use nat_rl::coordinator::advantage::grouped_advantages;
use nat_rl::coordinator::batcher::{
    allocated_tokens, micro_shapes, pack, pack_budget, split_zero_contribution, LearnItem,
};
use nat_rl::coordinator::masking;
use nat_rl::coordinator::masking::rpc_survival;
use nat_rl::coordinator::rollout::{encode_prompt, trim_at_eos};
use nat_rl::tasks::render::render_cot;
use nat_rl::tasks::verify::reward_text;
use nat_rl::tasks::{EvalSet, TaskMix, TaskSampler, Tier};
use nat_rl::tokenizer::{Tokenizer, EOS, PAD};
use nat_rl::util::rng::Rng;

const P: usize = 48;
const T_MAX: usize = 128;
const BUCKETS: [usize; 4] = [32, 64, 96, 128];

/// Build synthetic "rollouts" directly from gold CoTs — exercises the exact
/// data path the trainer uses, minus the model.
fn fake_rollouts(n_tasks: usize, g: usize, seed: u64) -> (Vec<LearnItem>, Vec<f32>) {
    let tok = Tokenizer::new();
    let mut sampler = TaskSampler::new(seed, TaskMix::default());
    let mut rng = Rng::new(seed ^ 77);
    let mut items = Vec::new();
    let mut rewards = Vec::new();
    for _ in 0..n_tasks {
        let task = sampler.next_task();
        let (prompt_row, pad) = encode_prompt(&tok, &task.prompt, P).unwrap();
        for j in 0..g {
            // half the group emits the gold CoT, half a corrupted answer
            let cot = if j % 2 == 0 {
                render_cot(&task)
            } else {
                format!("{}\n#999", render_cot(&task))
            };
            let mut resp: Vec<i32> = tok.try_encode(&cot).unwrap();
            resp.truncate(T_MAX - 1);
            resp.push(EOS);
            let resp_len = resp.len();
            let mut tokens = prompt_row.clone();
            tokens.extend_from_slice(&resp);
            tokens.resize(P + T_MAX, PAD);
            let reward = reward_text(&task, &tok.decode(&resp));
            rewards.push(reward);
            let m = masking::sample(&Method::Rpc { min_cut: 8 }, resp_len, &mut rng);
            items.push(LearnItem {
                tokens,
                pad_len: pad,
                resp_len,
                ht_w: m.ht_w,
                learn_len: m.learn_len,
                adv: 0.0, // filled below
                old_lp: vec![-1.0; resp_len],
            });
        }
    }
    (items, rewards)
}

#[test]
fn full_host_path_produces_consistent_micro_batches() {
    let g = 8;
    let (mut items, rewards) = fake_rollouts(4, g, 1);
    let advs = grouped_advantages(&rewards, g);
    for (it, &a) in items.iter_mut().zip(&advs) {
        it.adv = a;
    }
    let mbs = pack(&items, &BUCKETS, P, 8).unwrap();
    // every real row accounted for exactly once
    let total: usize = mbs.iter().map(|m| m.real_rows).sum();
    assert_eq!(total, items.len());
    for mb in &mbs {
        assert!(BUCKETS.contains(&mb.bucket));
        let b = mb.adv.len();
        assert_eq!(mb.tokens.len(), b * (P + mb.bucket));
        assert_eq!(mb.ht_w.len(), b * mb.bucket);
        assert_eq!(mb.old_lp.len(), b * mb.bucket);
        // inert padding rows
        for r in mb.real_rows..b {
            assert_eq!(mb.adv[r], 0.0);
            assert!(mb.ht_w[r * mb.bucket..(r + 1) * mb.bucket].iter().all(|&w| w == 0.0));
        }
        // ht weights live only inside the learner window
        for r in 0..mb.real_rows {
            let row = &mb.ht_w[r * mb.bucket..(r + 1) * mb.bucket];
            assert!(row.iter().all(|&w| w >= 0.0 && w.is_finite()));
        }
    }
    // memory model consumes the shapes
    let shapes = micro_shapes(&mbs, P);
    assert_eq!(shapes.len(), mbs.len());
}

#[test]
fn correct_completions_get_positive_advantage() {
    let g = 8;
    let (_, rewards) = fake_rollouts(3, g, 2);
    let advs = grouped_advantages(&rewards, g);
    for (chunk_r, chunk_a) in rewards.chunks(g).zip(advs.chunks(g)) {
        let any_signal = chunk_r.iter().any(|&r| r != chunk_r[0]);
        for (&r, &a) in chunk_r.iter().zip(chunk_a) {
            if any_signal {
                if r > 0.5 {
                    assert!(a > 0.0, "correct completion with non-positive advantage");
                } else {
                    assert!(a < 0.0);
                }
            } else {
                assert!(a.abs() < 1e-3);
            }
        }
    }
}

#[test]
fn rpc_routes_to_strictly_more_buckets_than_grpo() {
    let g = 8;
    let (items_rpc, _) = fake_rollouts(8, g, 3);
    // GRPO variant of the same items: full masks
    let mut items_grpo = items_rpc.clone();
    for it in &mut items_grpo {
        it.ht_w = vec![1.0; it.resp_len];
        it.learn_len = it.resp_len;
    }
    let distinct = |items: &[LearnItem]| {
        let mut b: Vec<usize> =
            pack(items, &BUCKETS, P, 8).unwrap().iter().map(|m| m.bucket).collect();
        b.sort();
        b.dedup();
        b
    };
    let rpc_buckets = distinct(&items_rpc);
    let grpo_buckets = distinct(&items_grpo);
    assert!(rpc_buckets.len() >= grpo_buckets.len());
    // GRPO with gold CoTs of varying length still lands in >= 1 buckets, but
    // never in a bucket below its response length; RPC must use smaller ones.
    let min_rpc = *rpc_buckets.first().unwrap();
    let min_grpo = *grpo_buckets.first().unwrap();
    assert!(min_rpc <= min_grpo);
}

/// Monte-Carlo: per-token HT inclusion expectations must SURVIVE packing —
/// reading the weights back out of budget-packed tensors reproduces the RPC
/// survival function, so the packed layout feeds the grad artifact exactly
/// the estimator the masking theory analysed.
#[test]
fn rpc_inclusion_expectations_survive_budget_packing() {
    const GRID: [usize; 4] = [1, 2, 4, 8];
    let (t_i, c, draws) = (100usize, 8usize, 4000usize);
    let mut rng = Rng::new(17);
    let mut counts = vec![0u32; t_i];
    let mut wsum = vec![0.0f64; t_i];
    for _ in 0..draws {
        // a group of 8 rows, one of which is the tracked length-t_i item
        let items: Vec<LearnItem> = (0..8)
            .map(|j| {
                let resp_len = if j == 0 { t_i } else { 1 + rng.below(T_MAX as u64) as usize };
                let m = masking::sample(&Method::Rpc { min_cut: c }, resp_len, &mut rng);
                LearnItem {
                    tokens: vec![7; P + T_MAX],
                    pad_len: 3,
                    resp_len,
                    ht_w: m.ht_w,
                    learn_len: m.learn_len,
                    adv: if j == 0 { 9.0 } else { 0.5 },
                    old_lp: vec![-1.0; resp_len],
                }
            })
            .collect();
        let mbs = pack_budget(&items, &BUCKETS, P, &GRID, 0).unwrap();
        // find the tracked row (unique adv marker) in the packed tensors
        let mut found = false;
        for mb in &mbs {
            for r in 0..mb.real_rows {
                if (mb.adv[r] - 9.0).abs() < 1e-6 {
                    assert!(!found, "tracked row packed twice");
                    found = true;
                    let row = &mb.ht_w[r * mb.bucket..(r + 1) * mb.bucket];
                    for (t, &w) in row.iter().enumerate() {
                        if w > 0.0 {
                            counts[t] += 1;
                            wsum[t] += w as f64;
                        }
                    }
                    // nothing beyond the bucket exists to read: positions
                    // >= bucket were never selected (hard-error guarantee)
                    assert!(mb.bucket >= items[0].learn_len);
                }
            }
        }
        assert!(found, "tracked row lost in packing");
    }
    let p = rpc_survival(t_i, c);
    for t in 0..t_i {
        let hat = counts[t] as f64 / draws as f64;
        assert!((hat - p[t] as f64).abs() < 0.05, "t={t}: {hat} vs {}", p[t]);
        // HT identity: E[m_t * w_t] == 1. Var[m w] = 1/p - 1 explodes at
        // the tail, so assert only where inclusion is common (>= 6 sigma
        // of MC headroom at these draw counts).
        if p[t] >= 0.5 {
            let mean_w = wsum[t] / draws as f64;
            assert!((mean_w - 1.0).abs() < 0.1, "t={t}: E[m w] = {mean_w}");
        }
    }
}

/// Zero-contribution rows (kept == 0 or adv == 0) may be dropped before
/// packing without changing anything the optimizer sees: the packed
/// gradient mass is identical, the apply scale denominator is restored by
/// the caller, and the pre-filter population still backs the
/// selected_ratio / resp_len accounting.
#[test]
fn zero_contribution_filter_preserves_step_semantics() {
    let g = 8;
    let (mut items, rewards) = fake_rollouts(6, g, 11);
    let advs = grouped_advantages(&rewards, g);
    for (it, &a) in items.iter_mut().zip(&advs) {
        it.adv = a;
    }
    // force some all-miss rows on top of the zero-variance groups
    for it in items.iter_mut().step_by(7) {
        it.ht_w = vec![0.0; it.resp_len];
    }
    let n = items.len();
    // gradient-relevant mass of a packed set: sum over rows/tokens of
    // ht_w * adv * inv_len * old_lp-weighted terms; any per-token linear
    // functional works — use ht_w * adv and ht_w * adv * old_lp.
    let mass = |mbs: &[nat_rl::coordinator::batcher::MicroBatch]| -> (f64, f64) {
        let mut m1 = 0.0;
        let mut m2 = 0.0;
        for mb in mbs {
            for r in 0..mb.rows {
                for t in 0..mb.bucket {
                    let w = mb.ht_w[r * mb.bucket + t] as f64 * mb.adv[r] as f64;
                    m1 += w;
                    m2 += w * mb.old_lp[r * mb.bucket + t] as f64;
                }
            }
        }
        (m1, m2)
    };
    const GRID: [usize; 4] = [1, 2, 4, 8];
    let unfiltered = pack_budget(&items, &BUCKETS, P, &GRID, 0).unwrap();
    let (kept, dropped) = split_zero_contribution(items.clone());
    let filtered = pack_budget(&kept, &BUCKETS, P, &GRID, 0).unwrap();
    // the apply scale denominator is fully restored
    let packed_rows: usize = filtered.iter().map(|m| m.real_rows).sum();
    assert_eq!(packed_rows + dropped, n);
    assert!(dropped > 0, "test should exercise the filter");
    // identical gradient-relevant content
    let (a1, a2) = mass(&unfiltered);
    let (b1, b2) = mass(&filtered);
    assert!((a1 - b1).abs() < 1e-6, "{a1} vs {b1}");
    assert!((a2 - b2).abs() < 1e-6, "{a2} vs {b2}");
    // and strictly less compute burnt
    assert!(allocated_tokens(&filtered, P) < allocated_tokens(&unfiltered, P));
}

#[test]
fn eval_sets_and_training_stream_do_not_overlap() {
    let mut sampler = TaskSampler::new(0, TaskMix::default());
    let train_prompts: std::collections::HashSet<String> =
        sampler.batch(500).into_iter().map(|t| t.prompt).collect();
    for tier in Tier::ALL {
        let eval = EvalSet::build(tier, 64, 1234);
        let overlap = eval.tasks.iter().filter(|t| train_prompts.contains(&t.prompt)).count();
        // prompts are drawn from the same task space; require near-disjoint
        assert!(overlap <= 3, "tier {tier:?}: {overlap} overlapping prompts");
    }
}

#[test]
fn trim_and_verify_interact_correctly_with_padding() {
    let tok = Tokenizer::new();
    let mut resp = tok.encode("1+1=2\n#2");
    resp.push(EOS);
    resp.extend(tok.encode("#junk"));
    resp.resize(T_MAX, PAD);
    let n = trim_at_eos(&resp);
    assert_eq!(n, 9);
    let decoded = tok.decode(&resp[..n]);
    assert_eq!(decoded, "1+1=2\n#2");
}

#[test]
fn selected_ratio_across_methods_matches_theory_on_real_lengths() {
    // Uses the actual response-length distribution induced by gold CoTs.
    let (items, _) = fake_rollouts(16, 4, 4);
    let mut rng = Rng::new(9);
    for (method, expect_fn) in [
        (Method::Urs { p: 0.5 }, 0.5f64),
        (Method::DetTrunc { frac: 0.5 }, 0.5),
    ] {
        let mut sel = 0usize;
        let mut tot = 0usize;
        for it in &items {
            for _ in 0..20 {
                let m = masking::sample(&method, it.resp_len, &mut rng);
                sel += m.kept;
                tot += it.resp_len;
            }
        }
        let ratio = sel as f64 / tot as f64;
        assert!((ratio - expect_fn).abs() < 0.05, "{method:?}: {ratio}");
    }
    // RPC ratio equals mean over items of 1/2 + C/(2 T_i)
    let c = 8usize;
    let expect: f64 = items
        .iter()
        .map(|it| masking::expected_ratio(&Method::Rpc { min_cut: c }, it.resp_len))
        .sum::<f64>()
        / items.len() as f64;
    let mut sel = 0.0;
    for it in &items {
        for _ in 0..50 {
            let m = masking::sample(&Method::Rpc { min_cut: c }, it.resp_len, &mut rng);
            sel += m.kept as f64 / it.resp_len as f64;
        }
    }
    let ratio = sel / (items.len() * 50) as f64;
    assert!((ratio - expect).abs() < 0.03, "{ratio} vs {expect}");
}
