//! Randomized property tests (in-tree generator; proptest is not vendored).
//!
//! Each property runs against hundreds of random inputs drawn from our
//! deterministic PRNG, with the failing seed printed on assertion failure —
//! the same workflow proptest gives, minus shrinking.

use nat_rl::config::Method;
use nat_rl::coordinator::advantage::group_advantages;
use nat_rl::coordinator::batcher::{
    alloc_rows, allocated_tokens, ideal_tokens, pack, pack_budget, LearnItem,
};
use nat_rl::coordinator::masking::{expected_ratio, rpc_survival, sample};
use nat_rl::coordinator::rollout::scheduler::{
    schedule, sim_workload, slot_seed, RolloutScheduler, SimBackend, SlotOut, SlotSpec,
};
use nat_rl::coordinator::rollout::trim_at_eos;
use nat_rl::stats::MeanCi;
use nat_rl::tokenizer::{Tokenizer, EOS};
use nat_rl::util::json::Json;
use nat_rl::util::rng::Rng;

/// Run `f` against `n` random cases, reporting the failing case seed.
fn for_cases(n: u64, f: impl Fn(u64, &mut Rng)) {
    for case in 0..n {
        let mut rng = Rng::new(0xBADC0DE ^ (case.wrapping_mul(0x9E3779B97F4A7C15)));
        f(case, &mut rng);
    }
}

#[test]
fn prop_rpc_survival_is_valid_inclusion_distribution() {
    for_cases(500, |case, rng| {
        let t_i = 1 + rng.below(400) as usize;
        let c = 1 + rng.below(200) as usize;
        let p = rpc_survival(t_i, c);
        assert_eq!(p.len(), t_i, "case {case}");
        assert!(p[0] == 1.0, "case {case}");
        assert!(p.iter().all(|&x| x > 0.0 && x <= 1.0), "case {case}");
        assert!(p.windows(2).all(|w| w[1] <= w[0] + 1e-7), "case {case}");
        // sum of survival == expected retained length == (C + T) / 2 for C<=T
        let cc = c.clamp(1, t_i) as f64;
        let sum: f64 = p.iter().map(|&x| x as f64).sum();
        let expect = (cc + t_i as f64) / 2.0;
        assert!((sum - expect).abs() < 1e-3, "case {case}: {sum} vs {expect}");
    });
}

#[test]
fn prop_masks_are_consistent_for_all_methods() {
    for_cases(500, |case, rng| {
        let t_i = 1 + rng.below(300) as usize;
        let methods = [
            Method::Grpo,
            Method::Urs { p: 0.05 + 0.95 * rng.uniform() },
            Method::DetTrunc { frac: 0.05 + 0.95 * rng.uniform() },
            Method::Rpc { min_cut: 1 + rng.below(100) as usize },
        ];
        for m in methods {
            let s = sample(&m, t_i, rng);
            assert_eq!(s.ht_w.len(), t_i, "case {case} {m:?}");
            assert_eq!(s.kept, s.ht_w.iter().filter(|&&w| w > 0.0).count(), "case {case} {m:?}");
            assert!(s.learn_len >= 1 && s.learn_len <= t_i, "case {case} {m:?}");
            assert!(s.ht_w.iter().all(|&w| w.is_finite() && w >= 0.0), "case {case} {m:?}");
            // prefix methods: weights form a contiguous prefix
            if matches!(m, Method::Rpc { .. } | Method::DetTrunc { .. } | Method::Grpo) {
                let kept = s.kept;
                assert!(s.ht_w[..kept].iter().all(|&w| w > 0.0), "case {case} {m:?}");
                assert!(s.ht_w[kept..].iter().all(|&w| w == 0.0), "case {case} {m:?}");
                assert_eq!(s.learn_len, kept.max(1), "case {case} {m:?}");
            }
        }
    });
}

#[test]
fn prop_ht_weight_sums_are_unbiased_for_unbiased_methods() {
    // For each random (t_i, method), E[sum_t w_t] == t_i within MC error.
    for_cases(20, |case, rng| {
        let t_i = 5 + rng.below(120) as usize;
        let methods = [
            Method::Urs { p: 0.2 + 0.8 * rng.uniform() },
            Method::Rpc { min_cut: 1 + rng.below(20) as usize },
        ];
        for m in methods {
            let n = 4000;
            let mut acc = 0.0f64;
            for _ in 0..n {
                acc += sample(&m, t_i, rng).ht_w.iter().map(|&w| w as f64).sum::<f64>();
            }
            let mean = acc / n as f64;
            let tol = t_i as f64 * 0.05 + 1.0;
            assert!((mean - t_i as f64).abs() < tol, "case {case} {m:?}: {mean} vs {t_i}");
        }
    });
}

#[test]
fn prop_expected_ratio_matches_empirical_ratio() {
    for_cases(15, |case, rng| {
        let t_i = 10 + rng.below(150) as usize;
        let m = Method::Rpc { min_cut: 1 + rng.below(30) as usize };
        let n = 3000;
        let mut acc = 0.0;
        for _ in 0..n {
            acc += sample(&m, t_i, rng).kept as f64 / t_i as f64;
        }
        let emp = acc / n as f64;
        let theory = expected_ratio(&m, t_i);
        assert!((emp - theory).abs() < 0.02, "case {case}: {emp} vs {theory}");
    });
}

#[test]
fn prop_group_advantages_are_zero_mean_and_scale_free() {
    for_cases(300, |case, rng| {
        let g = 2 + rng.below(14) as usize;
        let rewards: Vec<f32> = (0..g).map(|_| rng.bernoulli(0.4) as u8 as f32).collect();
        let advs = group_advantages(&rewards);
        let mean: f64 = advs.iter().map(|&a| a as f64).sum::<f64>() / g as f64;
        assert!(mean.abs() < 1e-4, "case {case}: mean {mean}");
        // scaling rewards by a constant offset leaves advantages unchanged
        let shifted: Vec<f32> = rewards.iter().map(|&r| r + 5.0).collect();
        let advs2 = group_advantages(&shifted);
        for (a, b) in advs.iter().zip(&advs2) {
            assert!((a - b).abs() < 1e-3, "case {case}");
        }
    });
}

#[test]
fn prop_batcher_conserves_rows_and_never_underruns_learn_len() {
    let buckets = [16usize, 32, 64, 128];
    let p = 32usize;
    for_cases(200, |case, rng| {
        let n = 1 + rng.below(40) as usize;
        let items: Vec<LearnItem> = (0..n)
            .map(|_| {
                let resp_len = 1 + rng.below(128) as usize;
                let learn_len = 1 + rng.below(resp_len as u64) as usize;
                LearnItem {
                    tokens: vec![7; p + 128],
                    pad_len: rng.below(p as u64) as usize,
                    resp_len,
                    ht_w: (0..resp_len)
                        .map(|t| if t < learn_len { 1.0 } else { 0.0 })
                        .collect(),
                    learn_len,
                    adv: rng.normal() as f32,
                    old_lp: vec![-1.0; resp_len],
                }
            })
            .collect();
        let batch = 1 + rng.below(8) as usize;
        let mbs = pack(&items, &buckets, p, batch).unwrap();
        let total: usize = mbs.iter().map(|m| m.real_rows).sum();
        assert_eq!(total, n, "case {case}");
        for mb in &mbs {
            assert!(mb.real_rows <= batch, "case {case}");
            assert_eq!(mb.rows, batch, "case {case}: fixed packer allocates full rows");
            assert!(buckets.contains(&mb.bucket), "case {case}");
        }
        // every item's bucket >= its learn_len (no truncation of selected tokens)
        for item in &items {
            let b = buckets.iter().find(|&&b| b >= item.learn_len);
            assert!(b.is_some(), "case {case}");
        }
    });
}

/// The budget packer is a pure RE-LAYOUT: for any bucket set, row grid and
/// token budget, every item's tensors must reappear exactly once in the
/// packed micro-batches, bit-for-bit, with only inert padding added.
#[test]
fn prop_budget_packing_is_a_lossless_relayout() {
    const P: usize = 32;
    const T: usize = 128;
    let bucket_sets: [&[usize]; 4] =
        [&[128], &[64, 128], &[32, 64, 96, 128], &[16, 48, 128]];
    let row_grids: [&[usize]; 4] = [&[8], &[1, 8], &[1, 2, 4, 8], &[2, 4, 6]];
    let budgets = [0usize, 512, 1024, 4096];
    for_cases(150, |case, rng| {
        let n = 1 + rng.below(40) as usize;
        let items: Vec<LearnItem> = (0..n)
            .map(|i| {
                let resp_len = 1 + rng.below(T as u64) as usize;
                let learn_len = 1 + rng.below(resp_len as u64) as usize;
                LearnItem {
                    tokens: (0..(P + T)).map(|_| rng.below(50) as i32).collect(),
                    pad_len: rng.below(P as u64) as usize,
                    resp_len,
                    // arbitrary weights, zeros allowed inside the prefix;
                    // adv is unique per item so rows can be matched back
                    ht_w: (0..resp_len)
                        .map(|t| {
                            if t < learn_len && rng.bernoulli(0.8) {
                                rng.uniform() as f32 + 0.1
                            } else {
                                0.0
                            }
                        })
                        .collect(),
                    learn_len,
                    adv: (i as f32 + 1.0) * 0.37,
                    old_lp: (0..resp_len).map(|_| -(rng.uniform() as f32) - 0.01).collect(),
                }
            })
            .collect();
        let buckets = bucket_sets[rng.below(4) as usize];
        let grid = row_grids[rng.below(4) as usize];
        // a budget must fit at least one allocated row of the top bucket;
        // draw between that floor and a non-binding 0
        let min_budget = alloc_rows(grid, 1) * (P + 128);
        let budget = [0, 0, min_budget, 3 * min_budget][rng.below(4) as usize];
        let mbs = pack_budget(&items, buckets, P, grid, budget).unwrap();

        let effective = if budget == 0 { grid.last().unwrap() * (P + 128) } else { budget };
        let total: usize = mbs.iter().map(|m| m.real_rows).sum();
        assert_eq!(total, n, "case {case}");
        let mut seen = vec![false; n];
        for mb in &mbs {
            assert!(buckets.contains(&mb.bucket), "case {case}");
            assert!(grid.contains(&mb.rows), "case {case}");
            assert_eq!(mb.rows, alloc_rows(grid, mb.real_rows), "case {case}");
            assert!(mb.rows * (P + mb.bucket) <= effective, "case {case}");
            let s = P + mb.bucket;
            for r in 0..mb.real_rows {
                // match the row back to its source item via the unique adv
                let i = items
                    .iter()
                    .position(|it| (it.adv - mb.adv[r]).abs() < 1e-6)
                    .unwrap_or_else(|| panic!("case {case}: unmatched row"));
                assert!(!seen[i], "case {case}: item {i} packed twice");
                seen[i] = true;
                let it = &items[i];
                assert!(mb.bucket >= it.learn_len, "case {case}");
                assert_eq!(&mb.tokens[r * s..(r + 1) * s], &it.tokens[..s], "case {case}");
                let w = &mb.ht_w[r * mb.bucket..(r + 1) * mb.bucket];
                let lp = &mb.old_lp[r * mb.bucket..(r + 1) * mb.bucket];
                assert_eq!(&w[..it.learn_len], &it.ht_w[..it.learn_len], "case {case}");
                assert!(w[it.learn_len..].iter().all(|&x| x == 0.0), "case {case}");
                assert_eq!(&lp[..it.learn_len], &it.old_lp[..it.learn_len], "case {case}");
                assert!(lp[it.learn_len..].iter().all(|&x| x == 0.0), "case {case}");
                assert!((mb.inv_len[r] - 1.0 / it.resp_len as f32).abs() < 1e-7, "case {case}");
                assert_eq!(mb.pad_len[r], it.pad_len as i32, "case {case}");
            }
            // padding rows are inert
            for r in mb.real_rows..mb.rows {
                assert_eq!(mb.adv[r], 0.0, "case {case}");
                assert_eq!(mb.inv_len[r], 0.0, "case {case}");
                assert!(
                    mb.ht_w[r * mb.bucket..(r + 1) * mb.bucket].iter().all(|&x| x == 0.0),
                    "case {case}"
                );
            }
        }
        assert!(seen.iter().all(|&s| s), "case {case}: item lost in packing");
        // with a non-binding budget the packer never allocates MORE tokens
        // than the fixed layout (the fixed grouping is in its search space)
        if budget == 0 {
            let fixed = pack(&items, buckets, P, *grid.last().unwrap()).unwrap();
            assert!(
                allocated_tokens(&mbs, P) <= allocated_tokens(&fixed, P),
                "case {case}: budget packer regressed allocation"
            );
        }
        assert!(ideal_tokens(&items, P) <= allocated_tokens(&mbs, P), "case {case}");
    });
}

/// Tentpole acceptance: for the same `(seed, step)` slot plan, the bucketed
/// rollout scheduler yields byte-identical outputs for ANY device batch
/// size, bucket-edge set (same top), and initial routing / refill
/// interleaving — rollout is a pure function of the plan.
#[test]
fn prop_bucketed_rollouts_are_scheduling_invariant() {
    const P: usize = 8;
    const TOP: usize = 64;
    for_cases(60, |case, rng| {
        let n_prompts = 1 + rng.below(6) as usize;
        let g = 1 + rng.below(5) as usize;
        let encoded: Vec<(Vec<i32>, usize)> = (0..n_prompts)
            .map(|_| {
                let pad = rng.below(P as u64 / 2) as usize;
                let mut row = vec![0i32; P];
                for slot in row.iter_mut().skip(pad) {
                    *slot = 3 + rng.below(50) as i32;
                }
                (row, pad)
            })
            .collect();
        let (run_seed, step) = (rng.next_u64(), rng.below(1000));
        let slots: Vec<SlotSpec> = (0..n_prompts * g)
            .map(|f| SlotSpec {
                flat_id: f,
                prompt_idx: f / g,
                seed: slot_seed(run_seed, step, f as u64),
            })
            .collect();
        let mean_len = 3 + rng.below(50) as usize;
        let canon = |backend: &SimBackend, routes: &[usize]| {
            let (outs, _) = schedule(backend, &encoded, &slots, routes, 1.0).unwrap();
            let mut v: Vec<(usize, usize, Vec<i32>, Vec<u32>)> = outs
                .iter()
                .map(|o| {
                    (
                        o.flat_id,
                        o.resp_len,
                        o.tokens.clone(),
                        o.lp.iter().map(|x| x.to_bits()).collect(),
                    )
                })
                .collect();
            v.sort();
            v
        };
        // reference: single top bucket, batch 4 — the "no scheduling" run
        let reference = canon(
            &SimBackend { batch: 4, prompt_len: P, buckets: vec![TOP], mean_len },
            &vec![TOP; slots.len()],
        );
        for _ in 0..4 {
            let batch = 1 + rng.below(10) as usize;
            let mut buckets: Vec<usize> =
                (0..rng.below(4)).map(|_| 4 + rng.below(TOP as u64 - 8) as usize).collect();
            buckets.push(TOP);
            buckets.sort();
            buckets.dedup();
            let backend = SimBackend { batch, prompt_len: P, buckets, mean_len };
            // adversarial per-slot routing: arbitrary initial buckets
            let routes: Vec<usize> =
                slots.iter().map(|_| 1 + rng.below(TOP as u64) as usize).collect();
            assert_eq!(
                canon(&backend, &routes),
                reference,
                "case {case}: scheduling changed rollout output"
            );
        }
    });
}

/// Acceptance: at the ONE default workload shared with `bench_rollout`
/// (`scheduler::sim_workload` — same constants feed `BENCH_rollout.json`),
/// the bucketed+refill engine must allocate >= 25% fewer decode-token-steps
/// than the fixed engine's `chunks × B × max_resp`.
#[test]
fn bucketed_engine_cuts_decode_steps_by_25pct_at_default_workload() {
    let backend = sim_workload::backend();
    let encoded = sim_workload::prompts();
    let sched = RolloutScheduler::new(*sim_workload::BUCKETS.last().unwrap());
    let mut bucketed_steps = 0usize;
    for step in 0..sim_workload::STEPS {
        let slots = sim_workload::slots(step);
        let (outs, stats) = sched.run(&backend, &encoded, &slots, 1.0, step).unwrap();
        assert_eq!(outs.len(), sim_workload::SLOTS_PER_STEP);
        bucketed_steps += stats.decode_token_steps;
    }
    let fixed_steps = sim_workload::fixed_decode_steps();
    let saving = 1.0 - bucketed_steps as f64 / fixed_steps as f64;
    assert!(
        saving >= 0.25,
        "bucketed {bucketed_steps} vs fixed {fixed_steps}: saving {:.1}% < 25%",
        100.0 * saving
    );
}

/// Satellite: the shared-prefix prefill cache is a pure transparency layer.
/// For any slot plan, rollout outputs are byte-identical to the uncached
/// scheduler across cache capacities (zero, tight, unbounded), slot
/// insertion orders, warm re-runs, and 1-vs-2-thread pipelining over one
/// shared scheduler.
#[test]
fn prop_prefix_cache_is_output_invariant_across_capacity_order_and_workers() {
    const P: usize = 8;
    const TOP: usize = 32;
    for_cases(40, |case, rng| {
        let n_prompts = 1 + rng.below(4) as usize;
        let g = 1 + rng.below(4) as usize;
        let encoded: Vec<(Vec<i32>, usize)> = (0..n_prompts)
            .map(|_| {
                let pad = rng.below(P as u64 / 2) as usize;
                let mut row = vec![0i32; P];
                for slot in row.iter_mut().skip(pad) {
                    *slot = 3 + rng.below(50) as i32;
                }
                (row, pad)
            })
            .collect();
        let (run_seed, step) = (rng.next_u64(), rng.below(100));
        let slots: Vec<SlotSpec> = (0..n_prompts * g)
            .map(|f| SlotSpec {
                flat_id: f,
                prompt_idx: f / g,
                seed: slot_seed(run_seed, step, f as u64),
            })
            .collect();
        let backend =
            SimBackend { batch: 3, prompt_len: P, buckets: vec![8, TOP], mean_len: 6 };
        let canon = |outs: &[SlotOut]| {
            let mut v: Vec<(usize, usize, Vec<i32>, Vec<u32>)> = outs
                .iter()
                .map(|o| {
                    (
                        o.flat_id,
                        o.resp_len,
                        o.tokens.clone(),
                        o.lp.iter().map(|x| x.to_bits()).collect(),
                    )
                })
                .collect();
            v.sort();
            v
        };
        let reference = {
            let sched = RolloutScheduler::new(TOP);
            canon(&sched.run(&backend, &encoded, &slots, 1.0, step).unwrap().0)
        };
        for cap in [0usize, 200, 1 << 20] {
            let sched = RolloutScheduler::with_cache(TOP, cap);
            // adversarial insertion order: the cache sees prompts in a
            // shuffled sequence, so eviction/refcount epochs differ — the
            // outputs must not
            let mut shuffled = slots.clone();
            for i in (1..shuffled.len()).rev() {
                let j = rng.below(i as u64 + 1) as usize;
                shuffled.swap(i, j);
            }
            let (outs, stats) =
                sched.run(&backend, &encoded, &shuffled, 1.0, step).unwrap();
            assert_eq!(canon(&outs), reference, "case {case} cap {cap}");
            assert!(stats.prefill_hits <= stats.prefill_lookups, "case {case} cap {cap}");
            // one lookup per allocated row (padding + escalation re-decodes
            // included): lookups = calls × device batch
            assert_eq!(stats.prefill_lookups, stats.calls * 3, "case {case} cap {cap}");
            // warm re-run on the same scheduler instance: same outputs again
            let (outs2, _) = sched.run(&backend, &encoded, &slots, 1.0, step).unwrap();
            assert_eq!(canon(&outs2), reference, "case {case} cap {cap} warm");
        }
        // two pipeline workers share one scheduler (and one cache), each
        // producing a disjoint half of the slot plan concurrently
        let sched = RolloutScheduler::with_cache(TOP, 1 << 20);
        let h = slots.len() / 2;
        let (lo, hi) = slots.split_at(h);
        let (mut a, b) = std::thread::scope(|s| {
            let ja = s.spawn(|| sched.run(&backend, &encoded, lo, 1.0, step).unwrap().0);
            let jb = s.spawn(|| sched.run(&backend, &encoded, hi, 1.0, step).unwrap().0);
            (ja.join().unwrap(), jb.join().unwrap())
        });
        a.extend(b);
        assert_eq!(canon(&a), reference, "case {case}: 2-worker split diverged");
    });
}

#[test]
fn prop_tokenizer_roundtrips_arbitrary_alphabet_strings() {
    let tok = Tokenizer::new();
    let alphabet: Vec<char> = "0123456789+-*%()=,.:#> abcdefghijklmnopqrstuvwxyz\n".chars().collect();
    for_cases(300, |case, rng| {
        let len = rng.below(60) as usize;
        let s: String = (0..len).map(|_| *rng.choose(&alphabet)).collect();
        let ids = tok.encode(&s);
        assert_eq!(tok.decode(&ids), s, "case {case}");
        // EOS placed anywhere truncates exactly there
        if !ids.is_empty() {
            let cut = rng.below(ids.len() as u64) as usize;
            let mut with_eos = ids.clone();
            with_eos.insert(cut, EOS);
            assert_eq!(trim_at_eos(&with_eos), cut + 1, "case {case}");
        }
    });
}

#[test]
fn prop_json_roundtrips_random_values() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bernoulli(0.5)),
            2 => Json::Num((rng.normal() * 1e3).round() / 8.0),
            3 => {
                let len = rng.below(8) as usize;
                Json::Str(
                    (0..len)
                        .map(|_| *rng.choose(&['a', 'b', '"', '\\', '\n', 'x', '7']))
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for_cases(400, |case, rng| {
        let v = random_json(rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(v, back, "case {case}: {text}");
    });
}

#[test]
fn prop_mean_ci_contains_true_mean_for_gaussian_samples() {
    // 95% CI should contain the true mean ~95% of the time.
    let mut hits = 0;
    let n_trials = 400;
    for case in 0..n_trials {
        let mut rng = Rng::new(1000 + case);
        let xs: Vec<f64> = (0..5).map(|_| 3.0 + rng.normal()).collect();
        let ci = MeanCi::of(&xs);
        if (ci.mean - 3.0).abs() <= ci.ci95 {
            hits += 1;
        }
    }
    let rate = hits as f64 / n_trials as f64;
    assert!((0.90..=0.99).contains(&rate), "{rate}");
}
