//! Tier-1 gate for `nat lint`: the repo's own source tree must be clean,
//! the seeded fixture tree must trip every rule with exact counts, and the
//! pragma system must round-trip without ever silencing an unnamed rule.

use std::path::Path;

use nat_rl::analysis::{lint_source, pragma, run_lint};
use nat_rl::util::rng::Rng;

/// The whole `rust/src` tree satisfies the determinism / HT-unbiasedness
/// contracts. This is the test that makes "new subsystems land lint-clean"
/// a property of tier-1 rather than a review convention.
#[test]
fn repo_src_tree_is_lint_clean() {
    let root = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/src"));
    let report = run_lint(root).expect("lint pass runs over src");
    assert!(
        report.findings.is_empty(),
        "nat lint found contract violations in the source tree:\n{}",
        report.render_human()
    );
    assert!(report.files_scanned > 20, "suspiciously few files: {}", report.files_scanned);
}

/// The seeded fixture tree (never compiled) trips every rule R1–R6 plus the
/// P0 pragma meta-rule, with exact per-rule counts — so a rule that silently
/// stops firing breaks tier-1, not just CI.
#[test]
fn seeded_fixture_trips_every_rule() {
    let root = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/natlint"));
    let report = run_lint(root).expect("lint pass runs over the fixture tree");
    let counts = report.counts();
    for (slug, n) in [
        ("unordered-iter", 1usize),
        ("wallclock", 1),
        ("rng-discipline", 1),
        ("float-accum", 2),
        ("hot-panic", 2),
        ("lossy-cast", 1),
        ("pragma", 1),
    ] {
        assert_eq!(
            counts.get(slug),
            Some(&n),
            "rule {slug} count drifted:\n{}",
            report.render_human()
        );
    }
    assert_eq!(report.findings.len(), 9, "{}", report.render_human());
    assert_eq!(report.files_scanned, 4);
}

/// Randomized pragma round-trip: any nonempty rule subset in any order with
/// a random reason renders to a comment that parses back verbatim.
#[test]
fn randomized_pragma_render_parse_round_trip() {
    const SLUGS: [&str; 6] = [
        "unordered-iter",
        "wallclock",
        "rng-discipline",
        "float-accum",
        "hot-panic",
        "lossy-cast",
    ];
    // reasons may contain spaces, commas, dashes — everything but a quote
    const CHARSET: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789 -,().";
    let mut rng = Rng::new(0xA11A_57A7);
    for _ in 0..300 {
        let mut subset: Vec<&str> =
            SLUGS.iter().copied().filter(|_| rng.bernoulli(0.4)).collect();
        if subset.is_empty() {
            subset.push(SLUGS[rng.below(SLUGS.len() as u64) as usize]);
        }
        let len = 1 + rng.below(24) as usize;
        let mut reason: String = (0..len)
            .map(|_| CHARSET[rng.below(CHARSET.len() as u64) as usize] as char)
            .collect();
        if reason.trim().is_empty() {
            reason = "fixture".to_string();
        }
        let text = pragma::render(&subset, &reason);
        let parsed = pragma::parse(7, &text)
            .expect("rendered pragma is recognized")
            .expect("rendered pragma is well-formed");
        assert_eq!(parsed.rules, subset, "rules drifted through render/parse: {text}");
        assert_eq!(parsed.reason, reason, "reason drifted through render/parse: {text}");
        assert_eq!(parsed.line, 7);
    }
}

/// A pragma never silences a rule it does not name: one line tripping both
/// wallclock and hot-panic, waived for a random one of the two — the other
/// must still fire. Naming both is the only way to clear the line.
#[test]
fn pragma_never_silences_unnamed_rules() {
    let mut rng = Rng::new(0xBEEF);
    for _ in 0..50 {
        let (named, other) = if rng.bernoulli(0.5) {
            ("wallclock", "hot-panic")
        } else {
            ("hot-panic", "wallclock")
        };
        let src = format!(
            "{}\nlet t = Instant::now().elapsed().unwrap();\n",
            pragma::render(&[named], "fixture waiver")
        );
        let findings = lint_source("coordinator/trainer.rs", &src);
        assert_eq!(findings.len(), 1, "waiving {named} left: {findings:?}");
        assert_eq!(findings[0].slug, other);
    }
    let both = format!(
        "{}\nlet t = Instant::now().elapsed().unwrap();\n",
        pragma::render(&["wallclock", "hot-panic"], "fixture waiver")
    );
    assert!(lint_source("coordinator/trainer.rs", &both).is_empty());
}
