//! Sharded-learner acceptance tests (tier-1, no artifacts needed).
//!
//! The tentpole contract: `--train.shards K` splits one step's packed
//! micro-batches across K concurrent grad workers and recombines them with
//! a fixed-order tree reduction keyed by micro-batch id, so the summation
//! order — and therefore every float in the step — is a pure function of
//! the step plan. The proptest here sweeps K ∈ {1,2,3,4} × packer
//! {fixed,budget} × method {URS,RPC,Saliency,Stratified,Poisson} over
//! randomized rollout groups through the REAL `learn_stage` (on the
//! deterministic sim
//! runtime) and asserts identical `StepStats` and post-step parameter
//! hashes. A second test composes sharding with the full `Trainer` and the
//! pipelined trainer; the Monte-Carlo test (ignored by default, run in the
//! CI `--ignored` lane) proves HT unbiasedness of the saliency selector
//! through the full pack → shard → reduce path.

use nat_rl::config::{Method, Packer, RunConfig};
use nat_rl::coordinator::batcher::{
    pack_budget, pack_budget_with, plan_shards, split_zero_contribution, LearnItem,
};
use nat_rl::coordinator::masking;
use nat_rl::obs::Tracer;
use nat_rl::coordinator::pipeline::PipelineTrainer;
use nat_rl::coordinator::rollout::scheduler::SchedStats;
use nat_rl::coordinator::rollout::RolloutSeq;
use nat_rl::coordinator::trainer::{learn_stage, StepStats, Trainer};
use nat_rl::runtime::shard::{execute_shards, tree_reduce_into};
use nat_rl::runtime::sim::{init_params, sim_manifest};
use nat_rl::runtime::{GradAccum, GradMetrics, OptState, Runtime};
use nat_rl::tasks::Tier;
use nat_rl::tokenizer::PAD;
use nat_rl::util::rng::Rng;

mod common;
use common::fnv1a;

/// Bit-exact fingerprint of every non-timing `StepStats` field.
fn stats_bits(s: &StepStats) -> Vec<u64> {
    vec![
        s.step,
        s.reward_mean.to_bits(),
        s.entropy.to_bits(),
        s.clip_frac.to_bits(),
        s.kl.to_bits(),
        s.grad_norm.to_bits(),
        s.selected_ratio.to_bits(),
        s.budget_target.to_bits(),
        s.budget_realized.to_bits(),
        s.sel_var.to_bits(),
        s.resp_len_mean.to_bits(),
        s.padding_waste.to_bits(),
        s.mem_gb.to_bits(),
        s.peak_mem_gb.to_bits(),
        s.micro_batches as u64,
        s.sequences as u64,
    ]
}

/// Randomized rollout group: `prompts × g` completions with varied lengths
/// (including occasional degenerate empty responses), behaviour logprobs,
/// pads and binary rewards.
fn synth_seqs(
    rng: &mut Rng,
    prompts: usize,
    g: usize,
    p: usize,
    t_max: usize,
    allow_empty: bool,
) -> Vec<RolloutSeq> {
    (0..prompts * g)
        .map(|flat| {
            let resp_len = if allow_empty && rng.below(12) == 0 {
                0
            } else {
                1 + rng.below(t_max as u64) as usize
            };
            let mut tokens = vec![PAD; p + t_max];
            for (i, slot) in tokens.iter_mut().enumerate().take(p) {
                *slot = 3 + ((flat * 7 + i * 3) % 50) as i32;
            }
            for t in 0..resp_len {
                tokens[p + t] = 3 + ((flat * 11 + t * 5) % 50) as i32;
            }
            let old_lp: Vec<f32> =
                (0..resp_len).map(|_| -0.02 - rng.uniform() as f32).collect();
            RolloutSeq {
                task_idx: flat / g,
                tokens,
                pad_len: rng.below(8) as usize,
                resp_len,
                old_lp,
                reward: if rng.bernoulli(0.4) { 1.0 } else { 0.0 },
            }
        })
        .collect()
}

/// Two optimizer steps through the real `learn_stage` on the sim runtime;
/// returns (per-step stats fingerprints, per-step post-apply param hashes).
fn run_learn(
    rt: &Runtime,
    method: Method,
    packer: Packer,
    shards: usize,
    seqs: &[RolloutSeq],
    g: usize,
    case: u64,
) -> (Vec<Vec<u64>>, Vec<u64>) {
    let mut cfg = RunConfig::default();
    cfg.method = method;
    cfg.train.packer = packer;
    cfg.train.shards = shards;
    cfg.rl.group_size = g;
    cfg.rl.ppo_epochs = 2; // exercise the mask-resampled multi-epoch path
    let mut params = init_params(&rt.manifest);
    let mut opt = OptState::zeros(&rt.manifest);
    let mut acc = GradAccum::zeros(rt.manifest.param_count);
    let mut stats_out = Vec::new();
    let mut hashes = Vec::new();
    for step in 0..2u64 {
        let mut rng_mask = Rng::new(0x4D41_534B ^ case ^ (step << 32));
        let s = learn_stage(
            rt,
            &cfg,
            &mut params,
            &mut opt,
            &mut acc,
            None,
            &mut rng_mask,
            step + 1,
            seqs,
            &SchedStats::default(),
            &Tracer::off(),
        )
        .unwrap();
        stats_out.push(stats_bits(&s));
        hashes.push(fnv1a(&params.flat));
    }
    (stats_out, hashes)
}

/// THE acceptance proptest: `shards = K` is bit-identical to `shards = 1`
/// — every StepStats field and the post-step parameter hash — across
/// K ∈ {1,2,3,4}, both packers, and all three stochastic selection methods,
/// over randomized rollout groups.
#[test]
fn shards_k_is_bit_identical_to_shards_1_for_all_methods_and_packers() {
    let rt = Runtime::sim(sim_manifest());
    let d = rt.manifest.dims.clone();
    let methods = [
        Method::Urs { p: 0.4 },
        Method::Rpc { min_cut: 4 },
        Method::Saliency { floor: 0.3 },
        // the selection-subsystem plug-ins compose with sharding too
        Method::Stratified { p: 0.4 },
        Method::Poisson { k: 6 },
    ];
    for case in 0..10u64 {
        let mut rng = Rng::new(0x5348_4152_4421 ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let g = 4usize;
        let prompts = 2 + (case % 2) as usize;
        let seqs = synth_seqs(&mut rng, prompts, g, d.prompt_len, d.max_resp, true);
        for method in methods {
            for packer in [Packer::Fixed, Packer::Budget] {
                let base = run_learn(&rt, method, packer, 1, &seqs, g, case);
                for k in 2..=4usize {
                    let got = run_learn(&rt, method, packer, k, &seqs, g, case);
                    assert_eq!(
                        base, got,
                        "case {case} {method:?} {packer:?}: shards={k} diverged from shards=1"
                    );
                }
            }
        }
    }
}

/// Sharding composes with the full trainer and with rollout pipelining:
/// serial shards=1, serial shards=3 and pipelined (workers=1, shards=4)
/// runs of the same seed are bit-identical in parameters and every shared
/// metric series.
#[test]
fn sharded_trainer_composes_with_pipeline_bit_identically() {
    let rt = Runtime::sim(sim_manifest());
    let base = init_params(&rt.manifest);
    let cfg_for = |shards: usize, workers: usize| {
        let mut cfg = RunConfig::default();
        cfg.model = "sim".into();
        cfg.seed = 3;
        cfg.rl.tiers = vec![Tier::Easy];
        cfg.rl.prompts_per_step = 2;
        cfg.rl.group_size = 4;
        cfg.train.shards = shards;
        cfg.pipeline.workers = workers;
        cfg
    };
    let series = [
        "reward",
        "entropy",
        "selected_ratio",
        "budget_realized",
        "sel_var",
        "grad_norm",
        "kl",
        "padding_waste",
    ];

    let mut serial1 =
        Trainer::new(&rt, cfg_for(1, 0), base.clone(), OptState::zeros(&rt.manifest));
    serial1.train(3, false).unwrap();
    let mut serial3 =
        Trainer::new(&rt, cfg_for(3, 0), base.clone(), OptState::zeros(&rt.manifest));
    serial3.train(3, false).unwrap();
    assert_eq!(serial1.params.flat, serial3.params.flat, "serial shards=3 diverged");
    for s in series {
        assert_eq!(serial1.recorder.values(s), serial3.recorder.values(s), "series {s}");
    }

    let mut piped = PipelineTrainer::new(&rt, cfg_for(4, 1), base, OptState::zeros(&rt.manifest));
    piped.train(3, false).unwrap();
    assert_eq!(serial1.params.flat, piped.params.flat, "pipelined shards=4 diverged");
    for s in series {
        assert_eq!(serial1.recorder.values(s), piped.recorder.values(s), "series {s}");
    }
    // the run actually learned something (non-degenerate trace)
    assert_ne!(serial1.params.flat, init_params(&rt.manifest).flat);
}

/// Regression (issue satellite): a degenerate empty response row flows
/// through the whole learn stage — no panic, sane stats, counted in the
/// apply-scale denominator — and stays shard-invariant.
#[test]
fn degenerate_empty_response_row_flows_through_learn_stage() {
    let rt = Runtime::sim(sim_manifest());
    let d = rt.manifest.dims.clone();
    let mut rng = Rng::new(77);
    let mut seqs = synth_seqs(&mut rng, 1, 4, d.prompt_len, d.max_resp, false);
    seqs[1].resp_len = 0;
    seqs[1].old_lp = Vec::new();
    seqs[1].tokens = vec![PAD; d.prompt_len + d.max_resp];
    seqs[1].reward = 0.0;
    for packer in [Packer::Fixed, Packer::Budget] {
        let one = run_learn(&rt, Method::Rpc { min_cut: 4 }, packer, 1, &seqs, 4, 99);
        let two = run_learn(&rt, Method::Rpc { min_cut: 4 }, packer, 2, &seqs, 4, 99);
        assert_eq!(one, two, "{packer:?}: degenerate row broke shard invariance");

        let mut cfg = RunConfig::default();
        cfg.method = Method::Rpc { min_cut: 4 };
        cfg.train.packer = packer;
        cfg.rl.group_size = 4;
        let mut params = init_params(&rt.manifest);
        let mut opt = OptState::zeros(&rt.manifest);
        let mut acc = GradAccum::zeros(rt.manifest.param_count);
        let mut rng_mask = Rng::new(5);
        let s = learn_stage(
            &rt, &cfg, &mut params, &mut opt, &mut acc, None, &mut rng_mask, 1, &seqs,
            &SchedStats::default(), &Tracer::off(),
        )
        .unwrap();
        assert_eq!(s.sequences, 4, "{packer:?}");
        assert!(s.grad_norm.is_finite());
        assert!((0.0..=1.0).contains(&s.selected_ratio));
        assert!(s.resp_len_mean.is_finite());
    }
}

/// Deterministic tier-1 complement of `bench_train_step`'s wall-clock gate
/// (which asserts K=4 ≥ 1.5× but only runs under `cargo bench`): on the
/// SAME shared workload (`batcher::shard_workload`), the K=4 shard plan's
/// bottleneck token load must leave an ideal speedup of at least 1.5×, and
/// the workload must genuinely fan out (≥ 8 micro-batches). A change that
/// degrades the shard planner or collapses the packing fails here, in
/// `cargo test -q`, not just in a manually-run bench.
#[test]
fn shard_plan_cost_balance_supports_1p5x_speedup_at_k4() {
    use nat_rl::coordinator::batcher::{micro_batch_cost, shard_workload};

    let mbs = shard_workload::micro_batches();
    assert!(mbs.len() >= 8, "workload packed into only {} micro-batches", mbs.len());
    let p = shard_workload::PROMPT_LEN;
    let total: usize = mbs.iter().map(|m| micro_batch_cost(m, p)).sum();
    let plan = plan_shards(&mbs, p, 4);
    let max_load = plan
        .iter()
        .map(|ids| ids.iter().map(|&i| micro_batch_cost(&mbs[i], p)).sum::<usize>())
        .max()
        .unwrap();
    // ideal speedup = total / max_load; require >= 1.5 (i.e. 2*total >= 3*max)
    assert!(
        2 * total >= 3 * max_load,
        "K=4 shard plan bottleneck ({max_load} of {total} allocated tokens) \
         implies an ideal speedup below 1.5x"
    );
}

/// Compaction round-trip (issue satellite): prefix-shaped methods never
/// route to the `grad_K` grid (`routes_compact` requires a scattered plan),
/// so toggling `--train.compact` must be bit-identical end to end — every
/// StepStats field, the post-step parameter hash, and a ledger that prices
/// compaction as inactive (saving exactly 0) in both runs.
///
/// (Scattered methods under the compacted layout are covered by the main
/// proptest above: `RunConfig::default()` has `train.compact = true`, so
/// its Budget-packer legs already shard-propcheck the compacted path.)
#[test]
fn compact_toggle_is_bit_identical_for_prefix_shaped_methods() {
    let rt = Runtime::sim(sim_manifest());
    let d = rt.manifest.dims.clone();
    let methods = [Method::Grpo, Method::Rpc { min_cut: 4 }, Method::DetTrunc { frac: 0.6 }];
    for case in 0..4u64 {
        let mut rng = Rng::new(0xC0_4FAC ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let seqs = synth_seqs(&mut rng, 2, 4, d.prompt_len, d.max_resp, true);
        for method in methods {
            let run = |compact: bool| {
                let mut cfg = RunConfig::default();
                cfg.method = method;
                cfg.train.packer = Packer::Budget;
                cfg.train.compact = compact;
                cfg.rl.group_size = 4;
                cfg.rl.ppo_epochs = 2;
                let mut params = init_params(&rt.manifest);
                let mut opt = OptState::zeros(&rt.manifest);
                let mut acc = GradAccum::zeros(rt.manifest.param_count);
                let mut rng_mask = Rng::new(0x434F_4D50 ^ case);
                let s = learn_stage(
                    &rt, &cfg, &mut params, &mut opt, &mut acc, None, &mut rng_mask, 1,
                    &seqs, &SchedStats::default(), &Tracer::off(),
                )
                .unwrap();
                let saving = s.ledger.compact_saving();
                (stats_bits(&s), fnv1a(&params.flat), saving.to_bits())
            };
            let on = run(true);
            let off = run(false);
            assert_eq!(
                on, off,
                "case {case} {method:?}: --train.compact changed a prefix-shaped run"
            );
            assert_eq!(
                on.2,
                0.0f64.to_bits(),
                "case {case} {method:?}: prefix-shaped run priced a compaction saving"
            );
        }
    }
}

struct PopRow {
    t_r: usize,
    tokens: Vec<i32>,
    old_lp: Vec<f32>,
    adv: f32,
    pad_len: usize,
}

/// Monte-Carlo HT-unbiasedness for the saliency selector, measured through
/// the FULL pack → shard → reduce path (not `masking::sample` in
/// isolation): the sim grad's first parameter is linear in the HT weights,
/// so its expectation over mask draws has the closed form
/// `Σ_r adv_r / t_r · Σ_t (old_lp_t + tok_t / 1024)`. Mirrors the
/// `rpc_empirical_ratio` style with an explicit tolerance. Slow: runs in
/// the CI `cargo test -- --ignored` lane.
#[test]
#[ignore = "slow Monte-Carlo lane: cargo test -q -- --ignored"]
fn saliency_ht_unbiased_through_pack_shard_reduce_path() {
    let rt = Runtime::sim(sim_manifest());
    let d = rt.manifest.dims.clone();
    let (p, top) = (d.prompt_len, *d.buckets.last().unwrap());
    let row_grid = rt.manifest.row_grid();
    let method = Method::Saliency { floor: 0.3 };

    // Fixed population: 8 responses, varied lengths, positive advantages so
    // the expectation is safely away from zero.
    let mut pop_rng = Rng::new(0x4854_4D43);
    let rows: Vec<PopRow> = (0..8)
        .map(|r| {
            let t_r = 2 + pop_rng.below((top - 1) as u64) as usize; // 2..=top
            let mut tokens = vec![PAD; p + top];
            for (i, slot) in tokens.iter_mut().enumerate().take(p + t_r) {
                *slot = 3 + ((r * 13 + i * 7) % 50) as i32;
            }
            let old_lp: Vec<f32> =
                (0..t_r).map(|_| -0.02 - pop_rng.uniform() as f32).collect();
            PopRow { t_r, tokens, old_lp, adv: 0.5 + 0.25 * r as f32, pad_len: r % 5 }
        })
        .collect();
    let expected: f64 = rows
        .iter()
        .map(|row| {
            let sum: f64 = (0..row.t_r)
                .map(|t| row.old_lp[t] as f64 + row.tokens[p + t] as f64 / 1024.0)
                .sum();
            row.adv as f64 * sum / row.t_r as f64
        })
        .sum();
    assert!(expected.abs() > 0.5, "degenerate population: E = {expected}");

    let params = init_params(&rt.manifest);
    let lits = params.to_literals(&rt.manifest).unwrap();
    let trials = 4000u64;
    let mut est_sum = 0.0f64;
    for trial in 0..trials {
        let mut rng = Rng::new(0x5431 ^ trial.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let items: Vec<LearnItem> = rows
            .iter()
            .map(|row| {
                let m = masking::sample_ctx(&method, row.t_r, Some(&row.old_lp), &mut rng);
                LearnItem {
                    tokens: row.tokens.clone(),
                    pad_len: row.pad_len,
                    resp_len: row.t_r,
                    ht_w: m.ht_w,
                    learn_len: m.learn_len,
                    adv: row.adv,
                    old_lp: row.old_lp.clone(),
                }
            })
            .collect();
        // Full path: zero-contribution filter → budget pack → shard plan
        // (the shard count rotates 1..=4 across trials) → concurrent
        // execute → tree reduce.
        let (items, _dropped) = split_zero_contribution(items);
        let mbs = pack_budget(&items, &d.buckets, p, &row_grid, 0).unwrap();
        let plan = plan_shards(&mbs, p, 1 + (trial % 4) as usize);
        let leaves = execute_shards(&rt, &mbs, &lits, &plan, &Tracer::off(), 1).unwrap();
        let mut acc = GradAccum::zeros(rt.manifest.param_count);
        let mut met = GradMetrics::default();
        tree_reduce_into(&mut acc, &mut met, leaves);
        est_sum += acc.flat[0] as f64;
    }
    let mean = est_sum / trials as f64;
    let rel = ((mean - expected) / expected).abs();
    assert!(
        rel < 0.05,
        "HT estimate biased through pack/shard/reduce: mean {mean:.4} vs E {expected:.4} \
         (rel err {rel:.4}, tolerance 0.05)"
    );
}

/// Monte-Carlo HT-unbiasedness THROUGH the compacted layout (issue
/// satellite): URS at 50% keep makes scattered plans, which the budget
/// packer re-keys onto the `grad_K<k>_B<r>` kept-count grid. The sim grad's
/// first parameter sums `adv · (1/T) · Σ w_t (old_lp_t + tok_t/1024)` over
/// kept tokens in ascending original position in BOTH layouts (it is
/// key-independent), so the prefix path's closed form must hold for the
/// compacted pack → shard → reduce estimate too: E[w_t] = 1 under HT
/// weighting regardless of which artifact grid executed the row. Slow:
/// runs in the CI `cargo test -- --ignored` lane.
#[test]
#[ignore = "slow Monte-Carlo lane: cargo test -q -- --ignored"]
fn urs_ht_unbiased_through_compacted_pack_shard_reduce_path() {
    let rt = Runtime::sim(sim_manifest());
    let d = rt.manifest.dims.clone();
    let (p, top) = (d.prompt_len, *d.buckets.last().unwrap());
    let row_grid = rt.manifest.row_grid();
    let method = Method::Urs { p: 0.5 };

    let mut pop_rng = Rng::new(0x4B45_5054);
    let rows: Vec<PopRow> = (0..8)
        .map(|r| {
            let t_r = 2 + pop_rng.below((top - 1) as u64) as usize; // 2..=top
            let mut tokens = vec![PAD; p + top];
            for (i, slot) in tokens.iter_mut().enumerate().take(p + t_r) {
                *slot = 3 + ((r * 13 + i * 7) % 50) as i32;
            }
            let old_lp: Vec<f32> =
                (0..t_r).map(|_| -0.02 - pop_rng.uniform() as f32).collect();
            PopRow { t_r, tokens, old_lp, adv: 0.5 + 0.25 * r as f32, pad_len: r % 5 }
        })
        .collect();
    let expected: f64 = rows
        .iter()
        .map(|row| {
            let sum: f64 = (0..row.t_r)
                .map(|t| row.old_lp[t] as f64 + row.tokens[p + t] as f64 / 1024.0)
                .sum();
            row.adv as f64 * sum / row.t_r as f64
        })
        .sum();
    assert!(expected.abs() > 0.5, "degenerate population: E = {expected}");

    let params = init_params(&rt.manifest);
    let lits = params.to_literals(&rt.manifest).unwrap();
    let trials = 4000u64;
    let mut est_sum = 0.0f64;
    let mut compacted_mbs = 0usize;
    for trial in 0..trials {
        let mut rng = Rng::new(0x4B54 ^ trial.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let items: Vec<LearnItem> = rows
            .iter()
            .map(|row| {
                let m = masking::sample(&method, row.t_r, &mut rng);
                LearnItem {
                    tokens: row.tokens.clone(),
                    pad_len: row.pad_len,
                    resp_len: row.t_r,
                    ht_w: m.ht_w,
                    learn_len: m.learn_len,
                    adv: row.adv,
                    old_lp: row.old_lp.clone(),
                }
            })
            .collect();
        let (items, _dropped) = split_zero_contribution(items);
        let mbs = pack_budget_with(&items, &d.buckets, p, &row_grid, 0, true).unwrap();
        compacted_mbs += mbs.iter().filter(|m| m.gather.is_some()).count();
        let plan = plan_shards(&mbs, p, 1 + (trial % 4) as usize);
        let leaves = execute_shards(&rt, &mbs, &lits, &plan, &Tracer::off(), 1).unwrap();
        let mut acc = GradAccum::zeros(rt.manifest.param_count);
        let mut met = GradMetrics::default();
        tree_reduce_into(&mut acc, &mut met, leaves);
        est_sum += acc.flat[0] as f64;
    }
    // The workload must genuinely exercise the compacted grid, not silently
    // fall back to prefix rows: at 50% keep most scattered rows drop a
    // kept-count bucket.
    assert!(
        compacted_mbs > trials as usize / 2,
        "only {compacted_mbs} compacted micro-batches over {trials} trials"
    );
    let mean = est_sum / trials as f64;
    let rel = ((mean - expected) / expected).abs();
    assert!(
        rel < 0.05,
        "HT estimate biased through the COMPACTED pack/shard/reduce: mean {mean:.4} \
         vs E {expected:.4} (rel err {rel:.4}, tolerance 0.05)"
    );
}
