"""L2: the policy model and every AOT-exported computation.

A decoder-only transformer (RMSNorm + RoPE + SwiGLU, Qwen-style) standing in
for the paper's Qwen2.5-Math-7B / Qwen3-8B policies (DESIGN.md §2), plus the
jitted functions the Rust coordinator drives through PJRT:

  * ``generate``       — grouped rollout: prefill + KV-cache scan decode.
  * ``score``          — per-token logprob + entropy of given tokens
                         (optionally through the Pallas flash-attention L1).
  * ``nat_grad``       — the NAT learner: forward over a *length bucket*,
                         HT-masked clipped GRPO surrogate via the Pallas
                         nat_loss L1 kernel, grads w.r.t. all params.
  * ``nat_grad_compact`` — the same learner on the gather-compacted layout:
                         rows carry only KEPT tokens (a *kept-count bucket*),
                         with a gather list mapping slots back to original
                         positions (the ``grad_K<k>_B<r>`` artifact grid).
  * ``adamw_apply``    — decoupled-weight-decay Adam with global-norm clip.
  * ``pretrain_step``  — fused CE grad + AdamW update (SFT base-model phase).

Layout convention shared with Rust: all token buffers are LEFT-padded to the
fixed prompt window P, so the response always occupies positions [P, P+T).
``plen`` carries the real prompt lengths for attention masking.

Everything here is build-time only; ``aot.py`` lowers each function once to
HLO text per (config, bucket) and Rust never imports Python.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.attention import flash_attention, prefill_attention
from compile.kernels.compact import compact_nat_loss
from compile.kernels.nat_loss import nat_loss_tokens


# --------------------------------------------------------------------------
# Configuration
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static shape/hyperparameter bundle. Mirrored in artifacts/manifest.json."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    prompt_len: int          # fixed left-padded prompt window P
    max_resp: int            # T_max — top length bucket
    buckets: Tuple[int, ...]  # learner length buckets (ascending, last == max_resp)
    batch_rollout: int       # B for generate/score artifacts
    batch_train: int         # B for grad artifacts
    pretrain_len: int        # sequence length of the SFT artifact
    batch_pretrain: int
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    eos_id: int = 2  # tokenizer EOS; used by early-exit generation
    # Optimisation constants (baked into apply/pretrain artifacts).
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    adam_eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    clip_eps: float = 0.2    # PPO/GRPO trust region
    pretrain_lr: float = 1e-3

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def seq_total(self) -> int:
        return self.prompt_len + self.max_resp


PRESETS = {
    # ~0.12M params — unit-test scale.
    # RL learning rates are deliberately much lower than the SFT rate —
    # the paper fine-tunes strong base models at 1e-5 (Qwen2.5) / 5e-7
    # (Qwen3); at 3e-4 the policy collapses its entropy and degrades.
    "tiny": ModelConfig(
        name="tiny", vocab=64, d_model=64, n_layers=2, n_heads=2, d_ff=176,
        prompt_len=32, max_resp=64, buckets=(16, 32, 48, 64),
        batch_rollout=8, batch_train=4, pretrain_len=96, batch_pretrain=16,
        lr=1e-4),
    # ~0.8M params — fast e2e runs (stands in for Qwen2.5-Math-7B).
    "small": ModelConfig(
        name="small", vocab=64, d_model=128, n_layers=4, n_heads=4, d_ff=352,
        prompt_len=48, max_resp=128, buckets=(32, 64, 96, 128),
        batch_rollout=16, batch_train=8, pretrain_len=176, batch_pretrain=16,
        lr=2e-5),
    # ~4.9M params — the main experiment scale (stands in for Qwen3-8B).
    "base": ModelConfig(
        name="base", vocab=64, d_model=256, n_layers=6, n_heads=8, d_ff=688,
        prompt_len=48, max_resp=192, buckets=(48, 96, 144, 192),
        batch_rollout=16, batch_train=8, pretrain_len=240, batch_pretrain=8,
        lr=2e-5),
    # ~91M params — scale proof (artifact build + a few steps; 1 CPU core).
    "xl": ModelConfig(
        name="xl", vocab=4096, d_model=768, n_layers=12, n_heads=12,
        d_ff=2048, prompt_len=64, max_resp=256, buckets=(64, 128, 192, 256),
        batch_rollout=4, batch_train=2, pretrain_len=320, batch_pretrain=2,
        lr=1e-5),
}


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic (name, shape) table — the contract with the Rust runtime."""
    spec: List[Tuple[str, Tuple[int, ...]]] = [("embed", (cfg.vocab, cfg.d_model))]
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        spec += [
            (p + "attn_norm", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.d_model)),
            (p + "wk", (cfg.d_model, cfg.d_model)),
            (p + "wv", (cfg.d_model, cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "mlp_norm", (cfg.d_model,)),
            (p + "w_gate", (cfg.d_model, cfg.d_ff)),
            (p + "w_up", (cfg.d_model, cfg.d_ff)),
            (p + "w_down", (cfg.d_ff, cfg.d_model)),
        ]
    spec += [("final_norm", (cfg.d_model,)), ("head", (cfg.d_model, cfg.vocab))]
    return spec


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jnp.ndarray]:
    """GPT-2-style init; residual-output projections scaled by 1/sqrt(2L)."""
    key = jax.random.PRNGKey(seed)
    out: List[jnp.ndarray] = []
    resid_scale = 1.0 / (2.0 * cfg.n_layers) ** 0.5
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("_norm"):
            out.append(jnp.ones(shape, jnp.float32))
        else:
            std = 0.02
            if name.endswith(("wo", "w_down")):
                std *= resid_scale
            out.append(std * jax.random.normal(sub, shape, jnp.float32))
    return out


def param_count(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.asarray(s))) for _, s in param_spec(cfg))


def _unflatten(cfg: ModelConfig, flat: Sequence[jnp.ndarray]) -> dict:
    d = {}
    for (name, _), arr in zip(param_spec(cfg), flat):
        d[name] = arr
    return d


# --------------------------------------------------------------------------
# Transformer forward
# --------------------------------------------------------------------------


def _rmsnorm(x, w, eps):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _rope(x, positions, theta):
    """x: [..., S, Hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention_dense(q, k, v, pad_len, key_valid=None):
    """jnp causal left-pad attention (default fwd/bwd path; XLA fuses this).

    ``key_valid`` ([B, S] bool, optional) additionally masks scattered
    invalid KEY slots — the gather-compacted layout's empty positions, which
    the prefix-shaped ``pad_len`` mask cannot express.
    """
    s = q.shape[2]
    scale = 1.0 / float(q.shape[-1]) ** 0.5
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    pos = jnp.arange(s)
    causal = pos[None, :, None] >= pos[None, None, :]
    valid = pos[None, None, :] >= pad_len[:, None, None]
    if key_valid is not None:
        valid = jnp.logical_and(valid, key_valid[:, None, :])
    mask = jnp.logical_and(causal, valid)[:, None, :, :]
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v)


def _block(cfg: ModelConfig, p: dict, prefix: str, x, pad_len, positions,
           use_pallas_attn: bool, key_valid=None):
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    xn = _rmsnorm(x, p[prefix + "attn_norm"], cfg.norm_eps)
    q = (xn @ p[prefix + "wq"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = (xn @ p[prefix + "wk"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = (xn @ p[prefix + "wv"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    q = _rope(q, positions[:, None, :], cfg.rope_theta)
    k = _rope(k, positions[:, None, :], cfg.rope_theta)
    if use_pallas_attn:
        assert key_valid is None, "flash_attention has no scattered key mask"
        o = flash_attention(q, k, v, pad_len)
    else:
        o = _attention_dense(q, k, v, pad_len, key_valid)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
    x = x + o @ p[prefix + "wo"]
    xn = _rmsnorm(x, p[prefix + "mlp_norm"], cfg.norm_eps)
    gate = jax.nn.silu(xn @ p[prefix + "w_gate"])
    x = x + (gate * (xn @ p[prefix + "w_up"])) @ p[prefix + "w_down"]
    return x


def forward(cfg: ModelConfig, flat_params, tokens, pad_len,
            use_pallas_attn: bool = False):
    """tokens [B, S] int32 -> logits [B, S, V]."""
    p = _unflatten(cfg, flat_params)
    b, s = tokens.shape
    x = p["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    for l in range(cfg.n_layers):
        x = _block(cfg, p, f"layer{l}.", x, pad_len, positions, use_pallas_attn)
    x = _rmsnorm(x, p["final_norm"], cfg.norm_eps)
    return x @ p["head"]


def forward_compact(cfg: ModelConfig, flat_params, tokens, gather, pad_len):
    """Gather-compacted forward: tokens [B, P+K] -> logits [B, P+K, V].

    Response slots hold only the KEPT tokens of each row, gathered left;
    ``gather [B, K] int32`` maps slot j to its original response position
    (-1 = empty slot past the row's kept count). Kept tokens keep their
    ORIGINAL RoPE positions (P + gather[j]) and attend the prompt plus
    earlier kept slots. Gather lists are strictly ascending per row, so
    index-order causality in the compacted sequence coincides with
    original-position causality, and the standard causal mask applies;
    empty slots are excluded as attention KEYS via ``key_valid`` (their
    query outputs are garbage and must be masked downstream, which the
    gathered ht_w == 0 / live == 0 slots of the NAT loss do).

    This is the compacted layout's defined semantics: dropped tokens are
    absent from the conditioning context (their KV is never computed — the
    source of the token saving), so scattered-selection logits differ from
    the full-prefix forward. Prefix-shaped plans never route here
    (``batcher::routes_compact``), keeping the legacy path bit-identical.
    """
    p = _unflatten(cfg, flat_params)
    b, s = tokens.shape
    P = cfg.prompt_len
    x = p["embed"][tokens]
    slot_pos = P + jnp.maximum(gather, 0)
    positions = jnp.concatenate(
        [jnp.broadcast_to(jnp.arange(P)[None, :], (b, P)), slot_pos], axis=1)
    key_valid = jnp.concatenate(
        [jnp.ones((b, P), jnp.bool_), gather >= 0], axis=1)
    for l in range(cfg.n_layers):
        x = _block(cfg, p, f"layer{l}.", x, pad_len, positions, False,
                   key_valid=key_valid)
    x = _rmsnorm(x, p["final_norm"], cfg.norm_eps)
    return x @ p["head"]


def _resp_logprobs(cfg, logits, tokens, resp_len):
    """Logprob+entropy of tokens[:, P:P+resp_len] from logits[:, P-1:...]."""
    P = cfg.prompt_len
    sel = logits[:, P - 1:P + resp_len - 1, :]
    lsm = jax.nn.log_softmax(sel, axis=-1)
    targets = tokens[:, P:P + resp_len]
    lp = jnp.take_along_axis(lsm, targets[..., None], axis=-1)[..., 0]
    ent = -jnp.sum(jnp.exp(lsm) * lsm, axis=-1)
    return lp, ent


# --------------------------------------------------------------------------
# Rollout: prefill + KV-cache decode scan
# --------------------------------------------------------------------------


def _decode_attention(q, k_cache, v_cache, pos, pad_len):
    """Single-position attention against a full-size cache.

    q: [B, H, 1, Hd]; caches [B, H, S_tot, Hd]; pos: scalar current index.
    """
    s_tot = k_cache.shape[2]
    scale = 1.0 / float(q.shape[-1]) ** 0.5
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k_cache) * scale  # [B,H,1,S]
    j = jnp.arange(s_tot)
    valid = jnp.logical_and(j[None, :] <= pos, j[None, :] >= pad_len[:, None])
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v_cache)


def prefill(cfg: ModelConfig, flat_params, prompts, pad_len,
            use_pallas_attn: bool = False):
    """Prompt-window prefill: per-layer prompt K/V plus the first logits.

    This is the per-prompt half of the prefill/decode split (the ``prefill``
    artifact). Its output is bucket-independent — caches cover only the
    prompt window [B, H, P, Hd] — so ONE prefill serves every decode bucket,
    which is what lets the rollout engine's shared-prefix cache prefill each
    prompt once and decode all G group siblings from the cached block.

    ``use_pallas_attn`` swaps the dense jnp attention for the L1 Pallas
    prompt-window kernel (``kernels.attention.prefill_attention``) — the
    ``prefill_pallas`` artifact, off the bit-identity path exactly like
    ``score_pallas``.

    Returns k_0..k_{L-1}, v_0..v_{L-1} ([B, H, P, Hd] each), then
    logits0 [B, V] (the distribution predicting position P).
    """
    p = _unflatten(cfg, flat_params)
    B, P = prompts.shape
    h, hd, L = cfg.n_heads, cfg.head_dim, cfg.n_layers
    x = p["embed"][prompts]
    positions = jnp.broadcast_to(jnp.arange(P)[None, :], (B, P))
    ks, vs = [], []
    for l in range(L):
        pre = f"layer{l}."
        xn = _rmsnorm(x, p[pre + "attn_norm"], cfg.norm_eps)
        q = (xn @ p[pre + "wq"]).reshape(B, P, h, hd).transpose(0, 2, 1, 3)
        k = (xn @ p[pre + "wk"]).reshape(B, P, h, hd).transpose(0, 2, 1, 3)
        v = (xn @ p[pre + "wv"]).reshape(B, P, h, hd).transpose(0, 2, 1, 3)
        q = _rope(q, positions[:, None, :], cfg.rope_theta)
        k = _rope(k, positions[:, None, :], cfg.rope_theta)
        if use_pallas_attn:
            o = prefill_attention(q, k, v, pad_len)
        else:
            o = _attention_dense(q, k, v, pad_len)
        o = o.transpose(0, 2, 1, 3).reshape(B, P, cfg.d_model)
        x = x + o @ p[pre + "wo"]
        xn = _rmsnorm(x, p[pre + "mlp_norm"], cfg.norm_eps)
        gate = jax.nn.silu(xn @ p[pre + "w_gate"])
        x = x + (gate * (xn @ p[pre + "w_up"])) @ p[pre + "w_down"]
        ks.append(k)
        vs.append(v)
    xn = _rmsnorm(x, p["final_norm"], cfg.norm_eps)
    logits0 = (xn @ p["head"])[:, -1, :]  # predicts position P
    return tuple(ks) + tuple(vs) + (logits0,)


def decode_from_kv(cfg: ModelConfig, flat_params, prompts, pad_len,
                   k_prompt, v_prompt, logits0, seed, temp,
                   early_exit: bool = True, t_max=None):
    """KV-consuming decode: the ``decode_T<b>`` artifact family.

    Resumes sampling from a prefilled prompt block — ``k_prompt``/``v_prompt``
    are the per-layer [B, H, P, Hd] caches and ``logits0`` the [B, V] first
    distribution, exactly as ``prefill`` returns them. The decode loop is the
    same code ``generate`` runs, so for any prompt block produced by
    ``prefill`` on the same parameters, decode-from-KV is bit-identical to
    the fused call (the prefix cache's determinism contract).

    Args:
      prompts: [B, P] int32 left-padded prompts (copied into the token
        buffer; attention reads the caches, not the prompt).
      pad_len: [B] int32 (P - true prompt length).
      seed:    int32 scalar OR int32 [B] per-row seeds (see ``generate``).
      temp:    f32 scalar sampling temperature.
      early_exit / t_max: as in ``generate``.

    Returns:
      tokens [B, P+T] int32, behaviour_lp [B, T] f32.
    """
    p = _unflatten(cfg, flat_params)
    B, P = prompts.shape
    T = cfg.max_resp if t_max is None else t_max
    S = P + T
    h, hd, L = cfg.n_heads, cfg.head_dim, cfg.n_layers

    # Widen the prompt-window caches into the bucket's full-size buffers.
    k_caches = [jnp.zeros((B, h, S, hd), jnp.float32).at[:, :, :P, :].set(k)
                for k in k_prompt]
    v_caches = [jnp.zeros((B, h, S, hd), jnp.float32).at[:, :, :P, :].set(v)
                for v in v_prompt]

    per_row = jnp.ndim(seed) == 1
    if per_row:
        row_keys = jax.vmap(jax.random.PRNGKey)(seed)  # [B, 2]
    else:
        key = jax.random.PRNGKey(seed)
    tokens0 = jnp.concatenate(
        [prompts, jnp.zeros((B, T), jnp.int32)], axis=1)

    def step(carry, t):
        caches_k, caches_v, logits, tokens = carry
        pos = P + t
        if per_row:
            keys_t = jax.vmap(jax.random.fold_in, (0, None))(row_keys, t)
            tok = jax.vmap(jax.random.categorical)(keys_t, logits / temp)  # [B]
        else:
            key_t = jax.random.fold_in(key, t)
            tok = jax.random.categorical(key_t, logits / temp, axis=-1)  # [B]
        lp_t = jnp.take_along_axis(
            jax.nn.log_softmax(logits, axis=-1), tok[:, None], axis=-1)[:, 0]
        tokens = jax.lax.dynamic_update_slice(
            tokens, tok[:, None], (0, pos))
        # One decode step at position `pos`.
        x = p["embed"][tok][:, None, :]  # [B, 1, D]
        posv = jnp.full((B, 1), pos, jnp.int32)
        new_k, new_v = [], []
        for l in range(L):
            pre = f"layer{l}."
            xn = _rmsnorm(x, p[pre + "attn_norm"], cfg.norm_eps)
            q = (xn @ p[pre + "wq"]).reshape(B, 1, h, hd).transpose(0, 2, 1, 3)
            k = (xn @ p[pre + "wk"]).reshape(B, 1, h, hd).transpose(0, 2, 1, 3)
            v = (xn @ p[pre + "wv"]).reshape(B, 1, h, hd).transpose(0, 2, 1, 3)
            q = _rope(q, posv[:, None, :], cfg.rope_theta)
            k = _rope(k, posv[:, None, :], cfg.rope_theta)
            kc = jax.lax.dynamic_update_slice(
                caches_k[l], k, (0, 0, pos, 0))
            vc = jax.lax.dynamic_update_slice(
                caches_v[l], v, (0, 0, pos, 0))
            o = _decode_attention(q, kc, vc, pos, pad_len)
            o = o.transpose(0, 2, 1, 3).reshape(B, 1, cfg.d_model)
            x = x + o @ p[pre + "wo"]
            xn = _rmsnorm(x, p[pre + "mlp_norm"], cfg.norm_eps)
            gate = jax.nn.silu(xn @ p[pre + "w_gate"])
            x = x + (gate * (xn @ p[pre + "w_up"])) @ p[pre + "w_down"]
            new_k.append(kc)
            new_v.append(vc)
        xn = _rmsnorm(x, p["final_norm"], cfg.norm_eps)
        logits_next = (xn @ p["head"])[:, 0, :]
        return (tuple(new_k), tuple(new_v), logits_next, tokens), lp_t

    if not early_exit:
        carry0 = (tuple(k_caches), tuple(v_caches), logits0, tokens0)
        (_, _, _, tokens), lps = jax.lax.scan(step, carry0, jnp.arange(T))
        return tokens, lps.T  # [B, P+T], [B, T]

    # Early-exit variant: while_loop with an all-rows-done predicate.
    lps0 = jnp.zeros((B, T), jnp.float32)
    done0 = jnp.zeros((B,), jnp.bool_)

    def cond(state):
        t, done, _ = state[0], state[1], state[2]
        return jnp.logical_and(t < T, jnp.logical_not(jnp.all(done)))

    def body(state):
        t, done, lps, carry = state
        carry, lp_t = step(carry, t)
        lps = jax.lax.dynamic_update_slice(lps, lp_t[:, None], (0, t))
        tok_t = jax.lax.dynamic_slice(
            carry[3], (0, P + t), (B, 1))[:, 0]
        done = jnp.logical_or(done, tok_t == cfg.eos_id)
        return (t + 1, done, lps, carry)

    carry0 = (tuple(k_caches), tuple(v_caches), logits0, tokens0)
    _, _, lps, carry = jax.lax.while_loop(
        cond, body, (jnp.int32(0), done0, lps0, carry0))
    return carry[3], lps


def generate(cfg: ModelConfig, flat_params, prompts, pad_len, seed, temp,
             early_exit: bool = True, t_max=None):
    """Sample up to ``t_max or cfg.max_resp`` tokens after the prompt window.

    Composed as ``prefill`` followed by ``decode_from_kv`` — the fused
    artifact and the split prefill/decode pair therefore share every op, so
    routing a row through the prefix cache can never change its tokens.

    Args:
      prompts: [B, P] int32 left-padded prompts.
      pad_len: [B] int32 (P - true prompt length).
      seed:    int32 scalar (per-call fresh randomness, the legacy layout)
               OR int32 [B] vector of PER-ROW seeds. With per-row seeds each
               row's sampling stream is a pure function of its own seed —
               independent of batch placement and of ``t_max`` (a longer cap
               extends the stream with a bit-identical prefix), which is the
               rollout scheduler's scheduling-invariance contract.
      temp:    f32 scalar sampling temperature (behaviour logprobs are always
               recorded at temperature 1.0 — the policy's own distribution).
      early_exit: lower the decode loop as a `while` that stops as soon as
        every row has emitted EOS (§Perf opt-1: rollouts whose longest
        response is L cost O(L) decode steps instead of O(T)). Produces
        bit-identical sampled prefixes to the fixed-trip scan because the
        per-step key is fold_in(key, t).
      t_max: response window cap (the bucketed ``generate_T<b>`` artifacts;
        None = cfg.max_resp).

    Returns:
      tokens [B, P+T] int32 (positions past each row's stop point stay PAD),
      behaviour_lp [B, T] f32.
    """
    out = prefill(cfg, flat_params, prompts, pad_len)
    L = cfg.n_layers
    return decode_from_kv(cfg, flat_params, prompts, pad_len,
                          out[:L], out[L:2 * L], out[2 * L], seed, temp,
                          early_exit, t_max)


def kv_flat_width(cfg: ModelConfig) -> int:
    """Per-row width of the flattened prefill block (see ``kv_flatten``)."""
    return (cfg.n_layers * 2 * cfg.n_heads * cfg.prompt_len * cfg.head_dim
            + cfg.vocab)


def kv_flatten(cfg: ModelConfig, out):
    """Pack a ``prefill`` output tuple into one [B, W] f32 row per prompt.

    Row layout (W = ``kv_flat_width``): per layer K then V, each
    [H, P, Hd] row-major — i.e. [layers, 2, heads, P, head_dim] — followed
    by logits0 [V]. The Rust runtime treats the row as an opaque blob
    (``KvBlock.kv``): it caches, concatenates, and hands it back to the
    decode artifact without inspecting the layout, so flatten and split
    only have to agree with each other.
    """
    L = cfg.n_layers
    ks, vs, logits0 = out[:L], out[L:2 * L], out[2 * L]
    B = logits0.shape[0]
    parts = []
    for k, v in zip(ks, vs):
        parts.append(k.reshape(B, -1))
        parts.append(v.reshape(B, -1))
    parts.append(logits0)
    return jnp.concatenate(parts, axis=1)


def kv_split(cfg: ModelConfig, prompt_len: int, kv_flat):
    """Inverse of ``kv_flatten``: [B, W] -> (k list, v list, logits0)."""
    B = kv_flat.shape[0]
    h, hd, L, P = cfg.n_heads, cfg.head_dim, cfg.n_layers, prompt_len
    sz = h * P * hd
    ks, vs = [], []
    for l in range(L):
        base = l * 2 * sz
        ks.append(kv_flat[:, base:base + sz].reshape(B, h, P, hd))
        vs.append(kv_flat[:, base + sz:base + 2 * sz].reshape(B, h, P, hd))
    logits0 = kv_flat[:, 2 * L * sz:]
    return ks, vs, logits0


def prefill_flat(cfg: ModelConfig, flat_params, prompts, pad_len,
                 use_pallas_attn: bool = False):
    """Single-output prefill: the ``prefill`` artifact ABI.

    ``Runtime::prefill`` expects exactly ONE output whose flattened f32
    vector is the cacheable per-prompt block, so the artifact lowers this
    wrapper (at B=1) rather than the tuple-returning ``prefill``.
    """
    return kv_flatten(
        cfg, prefill(cfg, flat_params, prompts, pad_len, use_pallas_attn))


def decode_from_flat_kv(cfg: ModelConfig, flat_params, prompts, pad_len,
                        kv_flat, seeds, temp, t_max):
    """Bucketed decode from flat blocks: the ``decode_T<b>`` artifact ABI.

    ``kv_flat`` is [B, W] — one ``prefill_flat`` row per batch row, exactly
    as the Rust runtime concatenates cached ``KvBlock.kv`` blobs. Delegates
    to ``decode_from_kv`` after ``kv_split``, so it inherits the
    bit-identity-with-fused-generate contract.
    """
    ks, vs, logits0 = kv_split(cfg, prompts.shape[1], kv_flat)
    return decode_from_kv(cfg, flat_params, prompts, pad_len, ks, vs,
                          logits0, seeds, temp, True, t_max=t_max)


# --------------------------------------------------------------------------
# Scoring, NAT gradient, optimiser, pretraining
# --------------------------------------------------------------------------


def score(cfg: ModelConfig, flat_params, tokens, pad_len, resp_len: int,
          use_pallas_attn: bool = False):
    """tokens [B, P+resp_len] -> (logprobs [B, resp_len], entropy [B, resp_len])."""
    logits = forward(cfg, flat_params, tokens, pad_len, use_pallas_attn)
    return _resp_logprobs(cfg, logits, tokens, resp_len)


def nat_grad(cfg: ModelConfig, flat_params, tokens, ht_w, adv, old_lp,
             inv_len, pad_len, bucket: int):
    """NAT learner gradient over one length-bucket micro-batch.

    tokens: [B, P+bucket]; ht_w/old_lp: [B, bucket]; adv/inv_len/pad_len: [B].
    Returns (grads list in param order, metrics [loss, tok, ent, clip, kl]).
    The scalar loss is a SUM over the micro-batch; the coordinator divides by
    the number of sequences in the full logical batch via ``scale`` at apply
    time, so gradient accumulation across buckets stays exact.
    """
    mask = (ht_w > 0.0).astype(jnp.float32)

    def loss_fn(params):
        logits = forward(cfg, params, tokens, pad_len)
        new_lp, ent = _resp_logprobs(cfg, logits, tokens, bucket)
        loss_tok, clip_ind = nat_loss_tokens(
            new_lp, old_lp, ht_w, adv, inv_len, cfg.clip_eps)
        loss = jnp.sum(loss_tok)
        tok = jnp.sum(mask)
        ent_sum = jnp.sum(jax.lax.stop_gradient(ent) * mask)
        clip_sum = jnp.sum(clip_ind * mask)
        kl_sum = jnp.sum((old_lp - jax.lax.stop_gradient(new_lp)) * mask)
        return loss, jnp.stack([loss, tok, ent_sum, clip_sum, kl_sum])

    (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        list(flat_params))
    return tuple(grads) + (metrics,)


def nat_grad_compact(cfg: ModelConfig, flat_params, tokens, ht_w, adv,
                     old_lp, inv_len, pad_len, gather, kbucket: int):
    """NAT learner gradient on a gather-compacted micro-batch.

    The ``grad_K<k>_B<r>`` artifact family: tokens [B, P+kbucket] hold the
    prompt plus each row's KEPT response tokens gathered left; ht_w/old_lp
    [B, kbucket] are gathered to the same slots (empty slots carry ht_w 0);
    ``gather [B, kbucket] int32`` maps slot -> original response position
    (-1 = empty). adv/inv_len/pad_len are per-row exactly as in ``nat_grad``.

    The surrogate math is pointwise in (new_lp, old_lp, ht_w), so it is the
    SAME loss as ``nat_grad`` evaluated on the gathered rows — the slot
    coordinate is the compacted layout's native gradient coordinate, and
    ``kernels.compact.scatter_rows`` maps d(new_lp) back to original
    positions when a full-layout view is needed. Metrics order matches
    ``nat_grad`` ([loss, tok, ent, clip, kl]) so the Rust runtime parses
    both families identically.
    """
    live = (gather >= 0).astype(jnp.float32)
    mask = (ht_w > 0.0).astype(jnp.float32) * live

    def loss_fn(params):
        logits = forward_compact(cfg, params, tokens, gather, pad_len)
        new_lp, ent = _resp_logprobs(cfg, logits, tokens, kbucket)
        loss_tok, clip_ind = compact_nat_loss(
            new_lp, old_lp, ht_w, live, adv, inv_len, cfg.clip_eps)
        loss = jnp.sum(loss_tok)
        tok = jnp.sum(mask)
        ent_sum = jnp.sum(jax.lax.stop_gradient(ent) * mask)
        clip_sum = jnp.sum(clip_ind * mask)
        kl_sum = jnp.sum((old_lp - jax.lax.stop_gradient(new_lp)) * mask)
        return loss, jnp.stack([loss, tok, ent_sum, clip_sum, kl_sum])

    (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        list(flat_params))
    return tuple(grads) + (metrics,)


def adamw_apply(cfg: ModelConfig, flat_params, m, v, step, grads, scale):
    """AdamW with decoupled weight decay and global-norm clipping.

    step: f32 scalar (1-based update index); scale: f32 multiplier applied to
    the accumulated gradient sums (1 / sequences-in-batch).
    Returns params', m', v', metrics [grad_norm_before_clip].
    """
    g = [gi * scale for gi in grads]
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(gi)) for gi in g))
    factor = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    g = [gi * factor for gi in g]
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step
    bc2 = 1.0 - b2 ** step
    new_p, new_m, new_v = [], [], []
    decay_skip = {i for i, (n, _) in enumerate(param_spec(cfg))
                  if n.endswith("_norm")}
    for i, (pi, mi, vi, gi) in enumerate(zip(flat_params, m, v, g)):
        mi = b1 * mi + (1.0 - b1) * gi
        vi = b2 * vi + (1.0 - b2) * jnp.square(gi)
        update = (mi / bc1) / (jnp.sqrt(vi / bc2) + cfg.adam_eps)
        wd = 0.0 if i in decay_skip else cfg.weight_decay
        pi = pi - cfg.lr * (update + wd * pi)
        new_p.append(pi)
        new_m.append(mi)
        new_v.append(vi)
    return tuple(new_p) + tuple(new_m) + tuple(new_v) + (jnp.stack([gnorm]),)


def pretrain_step(cfg: ModelConfig, flat_params, m, v, step, tokens,
                  loss_mask, pad_len):
    """Fused next-token CE gradient + AdamW update (SFT phase).

    tokens: [B, S_pt] int32 in the SAME layout as rollout/scoring — prompt
    LEFT-padded into the fixed window, response following it (so SFT and RL
    see identical RoPE positions and attention masks);
    loss_mask: [B, S_pt-1] f32 over predicted positions;
    pad_len: [B] int32 left-pad lengths.
    """

    def loss_fn(params):
        logits = forward(cfg, params, tokens, pad_len)
        lsm = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
        tgt = tokens[:, 1:]
        lp = jnp.take_along_axis(lsm, tgt[..., None], axis=-1)[..., 0]
        denom = jnp.maximum(jnp.sum(loss_mask), 1.0)
        return -jnp.sum(lp * loss_mask) / denom

    loss, grads = jax.value_and_grad(loss_fn)(list(flat_params))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(gi)) for gi in grads))
    factor = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step
    bc2 = 1.0 - b2 ** step
    new_p, new_m, new_v = [], [], []
    decay_skip = {i for i, (n, _) in enumerate(param_spec(cfg))
                  if n.endswith("_norm")}
    for i, (pi, mi, vi, gi) in enumerate(zip(flat_params, m, v, grads)):
        gi = gi * factor
        mi = b1 * mi + (1.0 - b1) * gi
        vi = b2 * vi + (1.0 - b2) * jnp.square(gi)
        update = (mi / bc1) / (jnp.sqrt(vi / bc2) + cfg.adam_eps)
        wd = 0.0 if i in decay_skip else cfg.weight_decay
        pi = pi - cfg.pretrain_lr * (update + wd * pi)
        new_p.append(pi)
        new_m.append(mi)
        new_v.append(vi)
    return (tuple(new_p) + tuple(new_m) + tuple(new_v)
            + (jnp.stack([loss, gnorm]),))
