"""L1 §Perf analysis: VMEM footprint + MXU-utilisation estimates per block
shape for the NAT-loss and flash-attention Pallas kernels.

interpret=True gives CPU-numpy timings only — not a TPU proxy — so the L1
optimisation target is structural (DESIGN.md §8): block shapes must
(a) fit comfortably in the ~16 MiB VMEM with room for double buffering,
(b) align to the f32 (8, 128) TPU tile, and (c) for attention, keep the MXU
contraction dimension >= 128 wherever possible.

Run: python -m compile.vmem_analysis
"""

from __future__ import annotations

VMEM_BYTES = 16 * 1024 * 1024
TILE = (8, 128)  # f32 sublane x lane


def nat_loss_vmem(bb: int, bt: int) -> dict:
    """Fwd kernel tiles: 3x [bb,bt] in, 2x [bb,1] in, 2x [bb,bt] out."""
    in_bytes = 4 * (3 * bb * bt + 2 * bb)
    out_bytes = 4 * (2 * bb * bt)
    total = in_bytes + out_bytes
    return {
        "block": (bb, bt),
        "bytes": total,
        "vmem_frac": total / VMEM_BYTES,
        "double_buffer_ok": 2 * total < VMEM_BYTES,
        "tile_aligned": bb % TILE[0] == 0 and bt % TILE[1] == 0,
    }


def attention_vmem(bq: int, bk: int, s: int, dh: int) -> dict:
    """Streaming state: q [bq,dh], one k/v block [bk,dh] each, score tile
    [bq,bk], online-softmax state (m,l [bq,1], acc [bq,dh])."""
    total = 4 * (bq * dh + 2 * bk * dh + bq * bk + 2 * bq + bq * dh)
    # MXU utilisation estimate: contraction dims of the two matmuls
    mxu = min(dh, 128) / 128 * min(bk, 128) / 128
    return {
        "block": (bq, bk),
        "bytes": total,
        "vmem_frac": total / VMEM_BYTES,
        "double_buffer_ok": 2 * total < VMEM_BYTES,
        "mxu_contraction_util": round(mxu, 3),
        "hbm_traffic_per_q_tile_bytes": 4 * 2 * s * dh,  # stream K+V once
    }


def main() -> None:
    print("NAT-loss kernel block sweep (chosen: 8x128)")
    print(f"{'block':>12} {'KiB':>8} {'vmem%':>7} {'2xbuf':>6} {'aligned':>8}")
    for bb, bt in [(1, 128), (8, 128), (8, 256), (8, 512), (16, 512), (64, 1024)]:
        r = nat_loss_vmem(bb, bt)
        print(f"{str(r['block']):>12} {r['bytes']/1024:>8.1f} "
              f"{100*r['vmem_frac']:>6.2f}% {str(r['double_buffer_ok']):>6} "
              f"{str(r['tile_aligned']):>8}")
    print("\nFlash-attention block sweep (chosen: 64x64, dh=64, S=256)")
    print(f"{'block':>12} {'KiB':>8} {'vmem%':>7} {'2xbuf':>6} {'mxu':>6}")
    for bq, bk in [(16, 16), (64, 64), (128, 128), (256, 128), (512, 256)]:
        r = attention_vmem(bq, bk, 256, 64)
        print(f"{str(r['block']):>12} {r['bytes']/1024:>8.1f} "
              f"{100*r['vmem_frac']:>6.2f}% {str(r['double_buffer_ok']):>6} "
              f"{r['mxu_contraction_util']:>6}")
    print(
        "\nReading: the NAT-loss tile (8,128) uses <0.1% of VMEM — the kernel\n"
        "is HBM-bandwidth-bound, so larger token tiles (8,512) amortise grid\n"
        "overhead while staying tile-aligned; whole-suffix tiles with ht_w==0\n"
        "can skip their HBM fetch under an RPC prefix schedule. Attention at\n"
        "(64,64) fits double-buffered with 25% MXU contraction utilisation on\n"
        "dh=64 heads; (128,128) reaches 100% lane utilisation and is the\n"
        "preferred real-TPU shape (kept at 64 here for interpret-mode test\n"
        "latency)."
    )


if __name__ == "__main__":
    main()
