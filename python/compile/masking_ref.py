"""Reference NAT token-selection schemes (mirrors rust/src/coordinator/masking.rs).

Used by the HT-unbiasedness statistical tests and the estimator-variance
study; NOT on any runtime path (the Rust coordinator owns mask sampling).
"""

from __future__ import annotations

import numpy as np


def urs_mask(rng: np.random.Generator, t_i: int, p: float):
    """Uniform random sampling: Bernoulli(p) per token; HT weight m/p."""
    m = (rng.random(t_i) < p).astype(np.float32)
    return m, m / p


def rpc_survival(t_i: int, c: int) -> np.ndarray:
    """p_{i,t} for L ~ Uniform({C..T}): 1 for t<=C, (T-t+1)/(T-C+1) after."""
    c = min(max(c, 1), t_i)
    t = np.arange(1, t_i + 1, dtype=np.float64)
    p = np.where(t <= c, 1.0, (t_i - t + 1) / (t_i - c + 1))
    return p.astype(np.float32)


def rpc_mask(rng: np.random.Generator, t_i: int, c: int):
    """Random prefix cutting with minimum cutoff C; HT weight 1/p_{i,t}."""
    c = min(max(c, 1), t_i)
    cut = int(rng.integers(c, t_i + 1))
    m = (np.arange(1, t_i + 1) <= cut).astype(np.float32)
    return m, m / rpc_survival(t_i, c)


def det_trunc_mask(t_i: int, frac: float = 0.5):
    """Deterministic prefix truncation (biased; p=0 on the suffix)."""
    k = max(1, int(np.floor(frac * t_i)))
    m = (np.arange(1, t_i + 1) <= k).astype(np.float32)
    return m, m.copy()  # no HT correction possible: weights are just the mask


def full_mask(t_i: int):
    m = np.ones(t_i, np.float32)
    return m, m.copy()


def stratified_mask(rng: np.random.Generator, t_i: int, p: float):
    """Systematic (stratified) sampling at rate p (mirrors
    rust selection::stratified): ONE uniform offset u places an
    equally-spaced grid over the cumulative rate; token t is selected iff
    floor(p*(t+1) + u) > floor(p*t + u). Marginal inclusion is exactly p
    (HT weight 1/p like URS) but the realized sample size is pinned to
    floor(p*t_i) or ceil(p*t_i) — URS's kept-count variance collapses."""
    u = float(rng.random())
    cum = np.floor(p * np.arange(1, t_i + 1) + u)
    prev = np.concatenate(([0.0], cum[:-1]))  # floor(p*0 + u) == 0 for u < 1
    m = (cum > prev).astype(np.float32)
    return m, m / p


def poisson_mask(rng: np.random.Generator, t_i: int, k: float):
    """Length-aware Poisson sampling (mirrors rust selection::poisson):
    independent Bernoulli at rate min(1, k / t_i), so every sequence
    contributes ~k selected tokens regardless of length; HT weight is the
    inverse rate (t_i / k for long sequences)."""
    rate = min(1.0, k / t_i)
    m = (rng.random(t_i) < rate).astype(np.float32)
    return m, m / rate
