"""AOT pipeline: lower every L2 computation to HLO *text* + manifest.

Run once per model config (``make artifacts``); the Rust coordinator then
drives training entirely through PJRT with no Python on the request path.

HLO text — not ``HloModuleProto.serialize()`` — is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Outputs under ``<out-dir>/<config>/``:
  generate.hlo.txt            rollout (prefill + KV-cache scan decode)
  generate_T<b>.hlo.txt       bucketed rollout, one per response bucket,
                              with PER-ROW sampling seeds (the continuous-
                              batching scheduler's grid; a row's stream is
                              independent of batch placement and bucket cap)
  prefill.hlo.txt             per-prompt prefill half of the split rollout:
                              B=1 prompt forward pass -> one flat KV row
                              (bucket-independent, so the shared-prefix
                              cache prefills each prompt ONCE per param
                              version and decodes all G siblings from it)
  decode_T<b>.hlo.txt         KV-consuming bucketed decode, one per bucket:
                              same decode loop as generate_T<b> but resumes
                              from cached prefill rows instead of re-running
                              the prompt forward pass
  score_T<b>.hlo.txt          logprob/entropy diagnostics (top bucket)
  grad_T<b>.hlo.txt           NAT learner gradient, one per length bucket
  grad_T<b>_B<r>.hlo.txt      same, for the sub-batch row grid {1,2,4,...}
                              (the token-budget packer's 2-D artifact grid)
  grad_K<k>_B<r>.hlo.txt      gather-compacted NAT gradient: rows carry only
                              KEPT tokens (kept-count bucket K) plus a
                              [B, K] gather list of original positions —
                              the grid the packer routes scattered-selection
                              micro-batches to (every (K, rows) cell is
                              emitted explicitly; no full-row fallback)
  apply.hlo.txt               AdamW with global-norm clip
  pretrain.hlo.txt            fused SFT step
  init_params.bin             raw little-endian f32, manifest order
  manifest.json               shapes/param-table/artifact index for Rust
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _param_specs(cfg):
    return [_spec(s) for _, s in M.param_spec(cfg)]


def lower_generate(cfg, early_exit=True):
    fn = lambda params, prompts, pad_len, seed, temp: M.generate(
        cfg, params, prompts, pad_len, seed, temp, early_exit)
    B, P = cfg.batch_rollout, cfg.prompt_len
    return jax.jit(fn).lower(
        _param_specs(cfg), _spec((B, P), jnp.int32), _spec((B,), jnp.int32),
        _spec((), jnp.int32), _spec((), jnp.float32))


def lower_generate_bucket(cfg, bucket):
    """Per-row-seed rollout capped at ``bucket`` decode steps.

    The seeds input is [B] int32 (one stream per row) instead of the legacy
    scalar: each row's sampled tokens depend only on its own seed, so the
    Rust scheduler can place a slot in any batch/bucket without changing its
    output — and escalate overflow rows to a larger bucket bit-identically.
    """
    fn = lambda params, prompts, pad_len, seeds, temp: M.generate(
        cfg, params, prompts, pad_len, seeds, temp, True, t_max=bucket)
    B, P = cfg.batch_rollout, cfg.prompt_len
    return jax.jit(fn).lower(
        _param_specs(cfg), _spec((B, P), jnp.int32), _spec((B,), jnp.int32),
        _spec((B,), jnp.int32), _spec((), jnp.float32))


def lower_prefill(cfg, use_pallas_attn=False):
    """Per-prompt prefill artifact: the B=1 half of the split rollout.

    Lowered at batch 1 because the rollout cache's unit of work is one
    prompt: ``Runtime::prefill`` runs it once per (param_version, prompt)
    miss and caches the single flat output row as the ``KvBlock`` every
    group sibling decodes from. The row layout is ``model.kv_flatten``'s
    ([layers, 2, heads, P, head_dim] then logits0); Rust never parses it —
    only ``decode_T<b>`` does.
    """
    fn = lambda params, prompt, pad_len: M.prefill_flat(
        cfg, params, prompt, pad_len, use_pallas_attn)
    P = cfg.prompt_len
    return jax.jit(fn).lower(
        _param_specs(cfg), _spec((1, P), jnp.int32), _spec((1,), jnp.int32))


def lower_decode_bucket(cfg, bucket):
    """KV-consuming decode capped at ``bucket`` steps.

    Input order matches ``lower_generate_bucket`` with one extra operand:
    the [B, W] flat KV matrix (W = ``model.kv_flat_width``) the Rust
    runtime assembles by concatenating cached per-prompt blocks. Seeds are
    per-row, so the scheduler's scheduling-invariance contract carries
    over: a row's output is a pure function of (prompt, seed) whether its
    prompt context came from a cache hit or a fresh prefill.
    """
    fn = lambda params, prompts, pad_len, kv, seeds, temp: \
        M.decode_from_flat_kv(cfg, params, prompts, pad_len, kv, seeds,
                              temp, bucket)
    B, P = cfg.batch_rollout, cfg.prompt_len
    return jax.jit(fn).lower(
        _param_specs(cfg), _spec((B, P), jnp.int32), _spec((B,), jnp.int32),
        _spec((B, M.kv_flat_width(cfg)), jnp.float32),
        _spec((B,), jnp.int32), _spec((), jnp.float32))


def lower_score(cfg, bucket, use_pallas_attn=False):
    fn = lambda params, tokens, pad_len: M.score(
        cfg, params, tokens, pad_len, bucket, use_pallas_attn)
    B, P = cfg.batch_rollout, cfg.prompt_len
    return jax.jit(fn).lower(
        _param_specs(cfg), _spec((B, P + bucket), jnp.int32),
        _spec((B,), jnp.int32))


def lower_grad(cfg, bucket, rows=None):
    """Lower the NAT grad for one (sequence bucket, rows) grid cell.

    ``rows=None`` is the legacy full-row artifact (B = batch_train); the
    token-budget packer additionally uses smaller row counts so ragged
    micro-batch tails do not pay a full batch of padding rows.
    """
    fn = lambda params, tokens, ht_w, adv, old_lp, inv_len, pad_len: \
        M.nat_grad(cfg, params, tokens, ht_w, adv, old_lp, inv_len, pad_len,
                   bucket)
    B, P = rows or cfg.batch_train, cfg.prompt_len
    return jax.jit(fn).lower(
        _param_specs(cfg), _spec((B, P + bucket), jnp.int32),
        _spec((B, bucket)), _spec((B,)), _spec((B, bucket)), _spec((B,)),
        _spec((B,), jnp.int32))


def lower_grad_compact(cfg, kbucket, rows=None):
    """Lower the gather-compacted NAT grad for one (kept bucket, rows) cell.

    Input arity/order matches ``lower_grad`` plus a trailing [B, K] int32
    gather operand — the Rust runtime appends the gather literal as the
    final batch input when a micro-batch carries one. Kept-count buckets
    reuse the sequence bucket edges, so the two grids share their K axis.
    """
    fn = lambda params, tokens, ht_w, adv, old_lp, inv_len, pad_len, gather: \
        M.nat_grad_compact(cfg, params, tokens, ht_w, adv, old_lp, inv_len,
                           pad_len, gather, kbucket)
    B, P = rows or cfg.batch_train, cfg.prompt_len
    return jax.jit(fn).lower(
        _param_specs(cfg), _spec((B, P + kbucket), jnp.int32),
        _spec((B, kbucket)), _spec((B,)), _spec((B, kbucket)), _spec((B,)),
        _spec((B,), jnp.int32), _spec((B, kbucket), jnp.int32))


def row_grid(batch_train):
    """Compiled batch dimensions below batch_train: powers of two, ascending.

    Mirrors the grid Rust's ``Manifest::row_grid`` reassembles (it appends
    batch_train itself, which the legacy ``grad`` artifacts cover).
    """
    rows, r = [], 1
    while r < batch_train:
        rows.append(r)
        r *= 2
    return rows


def lower_apply(cfg):
    fn = lambda params, m, v, step, grads, scale: M.adamw_apply(
        cfg, params, m, v, step, grads, scale)
    ps = _param_specs(cfg)
    return jax.jit(fn).lower(ps, ps, ps, _spec(()), ps, _spec(()))


def lower_pretrain(cfg):
    fn = lambda params, m, v, step, tokens, loss_mask, pad_len: M.pretrain_step(
        cfg, params, m, v, step, tokens, loss_mask, pad_len)
    ps = _param_specs(cfg)
    B, S = cfg.batch_pretrain, cfg.pretrain_len
    return jax.jit(fn).lower(
        ps, ps, ps, _spec(()), _spec((B, S), jnp.int32), _spec((B, S - 1)),
        _spec((B,), jnp.int32))


def build_manifest(cfg):
    params = []
    offset = 0
    for name, shape in M.param_spec(cfg):
        size = int(np.prod(shape))
        params.append({"name": name, "shape": list(shape), "size": size,
                       "offset": offset})
        offset += size
    return {
        "config": {
            "name": cfg.name, "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff, "prompt_len": cfg.prompt_len,
            "max_resp": cfg.max_resp, "buckets": list(cfg.buckets),
            "batch_rollout": cfg.batch_rollout,
            "batch_train": cfg.batch_train,
            "pretrain_len": cfg.pretrain_len,
            "batch_pretrain": cfg.batch_pretrain,
            "lr": cfg.lr, "clip_eps": cfg.clip_eps,
            "grad_clip": cfg.grad_clip, "pretrain_lr": cfg.pretrain_lr,
        },
        "param_count": sum(p["size"] for p in params),
        "params": params,
        "artifacts": {
            "generate": "generate.hlo.txt",
            "generate_full": "generate_full.hlo.txt",
            "generate_buckets": {str(b): f"generate_T{b}.hlo.txt"
                                 for b in cfg.buckets},
            "prefill": "prefill.hlo.txt",
            "prefill_pallas": "prefill_pallas.hlo.txt",
            "decode_buckets": {str(b): f"decode_T{b}.hlo.txt"
                               for b in cfg.buckets},
            "score": {str(cfg.buckets[-1]):
                      f"score_T{cfg.buckets[-1]}.hlo.txt"},
            "score_pallas": {str(cfg.buckets[-1]):
                             f"score_pallas_T{cfg.buckets[-1]}.hlo.txt"},
            "grad": {str(b): f"grad_T{b}.hlo.txt" for b in cfg.buckets},
            "grad_rows": {f"{b}x{r}": f"grad_T{b}_B{r}.hlo.txt"
                          for b in cfg.buckets
                          for r in row_grid(cfg.batch_train)},
            "grad_compact": {f"{k}x{r}": f"grad_K{k}_B{r}.hlo.txt"
                             for k in cfg.buckets
                             for r in row_grid(cfg.batch_train)
                             + [cfg.batch_train]},
            "apply": "apply.hlo.txt",
            "pretrain": "pretrain.hlo.txt",
        },
    }


def _source_fingerprint() -> str:
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _, files in os.walk(here):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def build(cfg_name: str, out_dir: str, force: bool = False) -> None:
    cfg = M.PRESETS[cfg_name]
    d = os.path.join(out_dir, cfg_name)
    os.makedirs(d, exist_ok=True)
    stamp = os.path.join(d, ".stamp")
    fp = _source_fingerprint()
    if not force and os.path.exists(stamp) and open(stamp).read() == fp:
        print(f"[aot] {cfg_name}: up to date")
        return

    def emit(name, lowered):
        text = to_hlo_text(lowered)
        path = os.path.join(d, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"[aot] {cfg_name}/{name}: {len(text) / 1e6:.2f} MB")

    emit("generate.hlo.txt", lower_generate(cfg, early_exit=True))
    emit("generate_full.hlo.txt", lower_generate(cfg, early_exit=False))
    # Bucketed per-row-seed generate grid for the continuous-batching
    # rollout scheduler (one artifact per response bucket).
    for b in cfg.buckets:
        emit(f"generate_T{b}.hlo.txt", lower_generate_bucket(cfg, b))
    # Prefill/decode split for the shared-prefix rollout cache: one
    # bucket-independent B=1 prefill, one KV-consuming decode per bucket.
    emit("prefill.hlo.txt", lower_prefill(cfg))
    for b in cfg.buckets:
        emit(f"decode_T{b}.hlo.txt", lower_decode_bucket(cfg, b))
    # Pallas prompt-window variant, mirroring score_pallas: proves the L1
    # attention kernel composes with the split rollout through rust PJRT.
    emit("prefill_pallas.hlo.txt", lower_prefill(cfg, use_pallas_attn=True))
    emit(f"score_T{cfg.buckets[-1]}.hlo.txt", lower_score(cfg, cfg.buckets[-1]))
    # same scorer with the L1 Pallas flash-attention kernel in the forward —
    # proves the attention kernel lowers and executes through rust PJRT.
    emit(f"score_pallas_T{cfg.buckets[-1]}.hlo.txt",
         lower_score(cfg, cfg.buckets[-1], use_pallas_attn=True))
    for b in cfg.buckets:
        emit(f"grad_T{b}.hlo.txt", lower_grad(cfg, b))
        # 2-D (bucket x rows) grid for the token-budget packer.
        for r in row_grid(cfg.batch_train):
            emit(f"grad_T{b}_B{r}.hlo.txt", lower_grad(cfg, b, rows=r))
        # Gather-compacted kept-count grid: every (K, rows) cell explicit —
        # the compact family has no legacy full-row artifact to fall back on.
        for r in row_grid(cfg.batch_train) + [cfg.batch_train]:
            emit(f"grad_K{b}_B{r}.hlo.txt", lower_grad_compact(cfg, b, rows=r))
    emit("apply.hlo.txt", lower_apply(cfg))
    emit("pretrain.hlo.txt", lower_pretrain(cfg))

    params = M.init_params(cfg, seed=0)
    flat = np.concatenate([np.asarray(p, np.float32).ravel() for p in params])
    flat.tofile(os.path.join(d, "init_params.bin"))
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(build_manifest(cfg), f, indent=1)
    with open(stamp, "w") as f:
        f.write(fp)
    print(f"[aot] {cfg_name}: done ({M.param_count(cfg):,} params)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", default="tiny,small,base",
                    help="comma-separated preset names (see model.PRESETS)")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    for name in args.config.split(","):
        build(name.strip(), args.out_dir, args.force)


if __name__ == "__main__":
    main()
