"""L1 Pallas kernel: fused NAT (HT-reweighted, token-masked) GRPO surrogate.

This is the paper's learner hot-spot expressed as a TPU-shaped kernel: the
per-token clipped importance-weighted surrogate (Eq. 3), multiplied by the
Horvitz-Thompson weight m_{i,t}/p_{i,t} and the per-sequence 1/T_i factor
(Eq. 6/9), fused into a single blocked pass so that ratio/clip/min/weighting
never materialise as separate [B, T] temporaries in HBM.

Hardware adaptation (DESIGN.md §6): the GPU implementation of NAT simply
masks the loss; on TPU we tile over (batch, token) blocks sized for VMEM.
Because RPC zeroes a contiguous *suffix*, whole token-tiles beyond the cut
have ht_w == 0 and — on a real TPU — their HBM->VMEM fetches are elided by
the BlockSpec prefix schedule. Here the kernel runs under interpret=True
(Mosaic custom-calls cannot execute on the CPU PJRT plugin), which lowers
the same logic to plain HLO; numerics are validated against kernels.ref.

The kernel is made differentiable with an explicit custom_vjp whose backward
pass is itself a Pallas kernel (analytic PPO-clip gradient), so the whole
train-step lowers into one HLO module.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shapes. 8 x 128 matches the float32 TPU tile (sublane x lane);
# token tiles of 128 keep the working set (6 input tiles + 2 output tiles,
# f32) at ~16 KiB << 16 MiB VMEM, leaving room for double buffering.
BLOCK_B = 8
BLOCK_T = 128


def _fwd_kernel(new_lp_ref, old_lp_ref, ht_w_ref, adv_ref, inv_len_ref,
                loss_ref, clip_ref, *, clip_eps):
    """One (BLOCK_B, BLOCK_T) tile of the fused surrogate."""
    ratio = jnp.exp(new_lp_ref[...] - old_lp_ref[...])
    adv = adv_ref[...]          # [bb, 1] — broadcast over the token tile
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    surrogate = jnp.minimum(unclipped, clipped)
    loss_ref[...] = -ht_w_ref[...] * surrogate * inv_len_ref[...]
    clip_ref[...] = (unclipped > clipped).astype(loss_ref.dtype)


def _bwd_kernel(g_ref, new_lp_ref, old_lp_ref, ht_w_ref, adv_ref, inv_len_ref,
                d_new_lp_ref, *, clip_eps):
    """Analytic gradient tile: d(loss)/d new_lp = -w * (1/T) * A * r * 1[u<=c]."""
    ratio = jnp.exp(new_lp_ref[...] - old_lp_ref[...])
    adv = adv_ref[...]
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    active = (unclipped <= clipped).astype(g_ref.dtype)
    d_new_lp_ref[...] = (-g_ref[...] * ht_w_ref[...] * inv_len_ref[...]
                         * adv * ratio * active)


def _pad_bt(x, bb, bt):
    b, t = x.shape
    pb = (-b) % bb
    pt = (-t) % bt
    if pb or pt:
        x = jnp.pad(x, ((0, pb), (0, pt)))
    return x


def _pad_b(x, bb):
    b = x.shape[0]
    pb = (-b) % bb
    if pb:
        x = jnp.pad(x, ((0, pb),))
    return x


def _tile_specs(bb, bt):
    tile2 = pl.BlockSpec((bb, bt), lambda i, j: (i, j))
    col = pl.BlockSpec((bb, 1), lambda i, j: (i, 0))
    return tile2, col


def _run_fwd(new_lp, old_lp, ht_w, adv, inv_len, clip_eps, bb, bt):
    b, t = new_lp.shape
    bb = min(bb, max(b, 1))
    bt = min(bt, max(t, 1))
    args = [_pad_bt(x, bb, bt) for x in (new_lp, old_lp, ht_w)]
    adv_p = _pad_b(adv, bb)[:, None]
    inv_p = _pad_b(inv_len, bb)[:, None]
    pb, ptt = args[0].shape
    tile2, col = _tile_specs(bb, bt)
    loss, clip_ind = pl.pallas_call(
        functools.partial(_fwd_kernel, clip_eps=clip_eps),
        grid=(pb // bb, ptt // bt),
        in_specs=[tile2, tile2, tile2, col, col],
        out_specs=[tile2, tile2],
        out_shape=[
            jax.ShapeDtypeStruct((pb, ptt), new_lp.dtype),
            jax.ShapeDtypeStruct((pb, ptt), new_lp.dtype),
        ],
        interpret=True,
    )(*args, adv_p, inv_p)
    return loss[:b, :t], clip_ind[:b, :t]


def _run_bwd(g, new_lp, old_lp, ht_w, adv, inv_len, clip_eps, bb, bt):
    b, t = new_lp.shape
    bb = min(bb, max(b, 1))
    bt = min(bt, max(t, 1))
    args = [_pad_bt(x, bb, bt) for x in (g, new_lp, old_lp, ht_w)]
    adv_p = _pad_b(adv, bb)[:, None]
    inv_p = _pad_b(inv_len, bb)[:, None]
    pb, ptt = args[0].shape
    tile2, col = _tile_specs(bb, bt)
    d_new = pl.pallas_call(
        functools.partial(_bwd_kernel, clip_eps=clip_eps),
        grid=(pb // bb, ptt // bt),
        in_specs=[tile2, tile2, tile2, tile2, col, col],
        out_specs=tile2,
        out_shape=jax.ShapeDtypeStruct((pb, ptt), new_lp.dtype),
        interpret=True,
    )(*args, adv_p, inv_p)
    return d_new[:b, :t]


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def nat_loss_tokens(new_lp, old_lp, ht_w, adv, inv_len, clip_eps,
                    block_b=BLOCK_B, block_t=BLOCK_T):
    """Fused NAT loss tile pass. Differentiable in ``new_lp`` only.

    Returns (loss_tok [B,T], clip_ind [B,T]); see kernels.ref for semantics.
    """
    return _run_fwd(new_lp, old_lp, ht_w, adv, inv_len, clip_eps,
                    block_b, block_t)


def _vjp_fwd(new_lp, old_lp, ht_w, adv, inv_len, clip_eps, block_b, block_t):
    out = _run_fwd(new_lp, old_lp, ht_w, adv, inv_len, clip_eps,
                   block_b, block_t)
    return out, (new_lp, old_lp, ht_w, adv, inv_len)


def _vjp_bwd(clip_eps, block_b, block_t, res, cts):
    new_lp, old_lp, ht_w, adv, inv_len = res
    g_loss, _g_clip = cts  # clip indicator is a non-differentiable statistic
    d_new = _run_bwd(g_loss, new_lp, old_lp, ht_w, adv, inv_len, clip_eps,
                     block_b, block_t)
    zeros_like = jnp.zeros_like
    return (d_new, zeros_like(old_lp), zeros_like(ht_w),
            zeros_like(adv), zeros_like(inv_len))


nat_loss_tokens.defvjp(_vjp_fwd, _vjp_bwd)
