"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the *correctness ground truth*: every Pallas kernel in this
package must match its oracle to float32 tolerance under pytest
(``python/tests/test_kernels.py`` sweeps shapes and values with hypothesis).
The oracles are also used by the HT-unbiasedness statistical tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def nat_loss_tokens_ref(new_lp, old_lp, ht_w, adv, inv_len, clip_eps):
    """Per-token HT-reweighted clipped GRPO surrogate (negative, for minimisation).

    Args:
      new_lp:  [B, T] log pi_theta(o_t | ...) of the sampled tokens.
      old_lp:  [B, T] log pi_theta_old(o_t | ...) (behaviour policy).
      ht_w:    [B, T] Horvitz-Thompson weights m_{i,t}/p_{i,t}; 0 for tokens
               excluded from the update (mask folded in).
      adv:     [B]    group-relative advantage, shared across tokens (GRPO).
      inv_len: [B]    1/T_i with T_i the FULL response length (the HT
               estimator normalises by the full length, not the retained one).
      clip_eps: PPO clip threshold (python float; baked at trace time).

    Returns:
      loss_tok: [B, T] per-token contribution to the scalar loss
                ``-(1/T_i) * (m/p) * S_{i,t}`` (Eq. 6/9 of the paper).
      clip_ind: [B, T] 1.0 where the clipped branch is active (ratio outside
                the trust region AND the min selected the clipped term).
    """
    ratio = jnp.exp(new_lp - old_lp)
    adv_b = adv[:, None]
    unclipped = ratio * adv_b
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv_b
    surrogate = jnp.minimum(unclipped, clipped)
    clip_ind = (unclipped > clipped).astype(new_lp.dtype)
    loss_tok = -ht_w * surrogate * inv_len[:, None]
    return loss_tok, clip_ind


def nat_loss_grad_ref(new_lp, old_lp, ht_w, adv, inv_len, clip_eps, g):
    """Analytic d(sum(g * loss_tok))/d new_lp for the reference loss.

    dS/d new_lp = A * r  when the unclipped branch is active (u <= c),
                  0      otherwise (the clip freezes the surrogate).
    """
    ratio = jnp.exp(new_lp - old_lp)
    adv_b = adv[:, None]
    unclipped = ratio * adv_b
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv_b
    active = (unclipped <= clipped).astype(new_lp.dtype)
    return -g * ht_w * inv_len[:, None] * adv_b * ratio * active


def causal_attention_ref(q, k, v, pad_len):
    """Left-pad-aware causal attention oracle.

    Args:
      q, k, v: [B, H, S, Dh].
      pad_len: [B] int32 — number of LEFT padding positions per sequence
               (keys j < pad_len[b] are invalid).
    Returns:
      [B, H, S, Dh].
    """
    b, h, s, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    pos = jnp.arange(s)
    causal = pos[None, :, None] >= pos[None, None, :]  # [1, q, k]
    valid = pos[None, None, :] >= pad_len[:, None, None]  # [b, 1, k]
    mask = jnp.logical_and(causal, valid)[:, None, :, :]
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v)
