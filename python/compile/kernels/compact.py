"""L1 Pallas kernels: gather-compacted NAT loss layout.

The token-budget packer can re-key a scattered-selection micro-batch on its
KEPT token count instead of its prefix length: each row carries only the
selected response tokens, gathered left into a kept-count bucket K, with
``gather [B, K] int32`` mapping slot j back to the original response
position (-1 marks an empty slot past the row's kept count). These kernels
are that layout's compute contract:

  * ``gather_rows``      — compact full [B, T] rows to [B, K] via the gather
                           list (the kernel-space image of the host-side
                           row-gather Rust's ``batcher::pack_one_compact``
                           performs when it builds the micro-batch buffers).
  * ``scatter_rows``     — the linear adjoint: place compacted values back
                           at their original response positions, zero
                           elsewhere. ``scatter_rows(gather_rows(x, g), g,
                           T)`` reproduces x on kept positions exactly.
  * ``compact_nat_loss`` — the fused NAT surrogate of ``kernels.nat_loss``
                           evaluated directly on the compacted layout. The
                           slot-validity mask ``live`` (1.0 where gather >=
                           0) rides along so empty slots contribute exactly
                           zero to the loss, the clip statistic, and the
                           gradient — independent of whatever padding values
                           occupy them. Its custom_vjp backward is the same
                           analytic PPO-clip gradient, emitted in compacted
                           coordinates; scattering it back by position
                           (``scatter_rows``) reproduces the kept-masked
                           full-layout gradient, the round-trip equivalence
                           python/tests/test_kernels.py asserts.

The surrogate math is position-free (pointwise in new_lp/old_lp/ht_w), so
compacting the rows commutes with the loss — which is exactly why the
``grad_K<k>_B<r>`` artifact family can price micro-batches on kept tokens
while the legacy ``grad_T<b>_B<r>`` grid prices prefixes.

Like nat_loss, everything runs under interpret=True (Mosaic custom-calls
cannot execute on the CPU PJRT plugin) and lowers to plain HLO inside the
grad_K artifacts; numerics are validated against kernels.ref plus the
full-layout nat_loss kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.nat_loss import BLOCK_B, BLOCK_T, _pad_b, _pad_bt, _tile_specs


def _pad_rows(x, bb, val=0):
    """Pad the batch axis of a 2-D array to a block multiple (gather lists
    pad with -1 so added rows hold no live slots)."""
    pb = (-x.shape[0]) % bb
    if pb:
        x = jnp.pad(x, ((0, pb), (0, 0)), constant_values=val)
    return x


# --------------------------------------------------------------------------
# Layout transforms: gather / scatter over the response axis
# --------------------------------------------------------------------------


def _gather_kernel(x_ref, g_ref, out_ref):
    """One batch-block: out[b, j] = x[b, g[b, j]] (0 where g < 0)."""
    g = g_ref[...]
    vals = jnp.take_along_axis(x_ref[...], jnp.maximum(g, 0), axis=1)
    out_ref[...] = jnp.where(g >= 0, vals, jnp.zeros_like(vals))


def _scatter_kernel(y_ref, g_ref, out_ref, *, t):
    """One batch-block: out[b, p] = sum_j y[b, j] * [g[b, j] == p]."""
    g = g_ref[...]
    y = jnp.where(g >= 0, y_ref[...], jnp.zeros_like(y_ref[...]))
    onehot = (g[..., None] == jnp.arange(t)[None, None, :]).astype(y.dtype)
    out_ref[...] = jnp.einsum("bk,bkt->bt", y, onehot)


def _row_specs(bb, widths):
    return [pl.BlockSpec((bb, w), lambda i: (i, 0)) for w in widths]


def gather_rows(x, gather, block_b=BLOCK_B):
    """Compact rows: x [B, T] f32, gather [B, K] int32 -> [B, K] f32.

    Slot j of row b takes x[b, gather[b, j]]; slots with gather < 0 read 0.
    Blocked over the batch axis only — each block sees whole rows, so the
    per-row dynamic gather stays inside one tile.
    """
    b, t = x.shape
    k = gather.shape[1]
    bb = min(block_b, max(b, 1))
    xp = _pad_rows(x, bb)
    gp = _pad_rows(gather, bb, val=-1)
    pb = xp.shape[0]
    in_specs = _row_specs(bb, [t, k])
    (out_spec,) = _row_specs(bb, [k])
    out = pl.pallas_call(
        _gather_kernel,
        grid=(pb // bb,),
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((pb, k), x.dtype),
        interpret=True,
    )(xp, gp)
    return out[:b]


def scatter_rows(y, gather, t, block_b=BLOCK_B):
    """Scatter back: y [B, K] f32, gather [B, K] int32 -> [B, T] f32.

    The exact linear adjoint of ``gather_rows``: position gather[b, j]
    receives y[b, j]; unreferenced positions are 0. Gather lists built by the
    packer are strictly ascending (no duplicates), but duplicate indices
    would sum — the correct adjoint semantics regardless.
    """
    b, k = y.shape
    bb = min(block_b, max(b, 1))
    yp = _pad_rows(y, bb)
    gp = _pad_rows(gather, bb, val=-1)
    pb = yp.shape[0]
    in_specs = _row_specs(bb, [k, k])
    (out_spec,) = _row_specs(bb, [t])
    out = pl.pallas_call(
        functools.partial(_scatter_kernel, t=t),
        grid=(pb // bb,),
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((pb, t), y.dtype),
        interpret=True,
    )(yp, gp)
    return out[:b]


# --------------------------------------------------------------------------
# Fused NAT surrogate on the compacted layout
# --------------------------------------------------------------------------


def _fwd_kernel(new_lp_ref, old_lp_ref, ht_w_ref, live_ref, adv_ref,
                inv_len_ref, loss_ref, clip_ref, *, clip_eps):
    """One (BLOCK_B, BLOCK_T) tile of the compacted surrogate."""
    live = live_ref[...]
    ratio = jnp.exp(new_lp_ref[...] - old_lp_ref[...])
    adv = adv_ref[...]          # [bb, 1] — broadcast over the slot tile
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    surrogate = jnp.minimum(unclipped, clipped)
    loss_ref[...] = -ht_w_ref[...] * surrogate * inv_len_ref[...] * live
    clip_ref[...] = (unclipped > clipped).astype(loss_ref.dtype) * live


def _bwd_kernel(g_ref, new_lp_ref, old_lp_ref, ht_w_ref, live_ref, adv_ref,
                inv_len_ref, d_new_lp_ref, *, clip_eps):
    """Analytic tile: d(loss)/d new_lp = -live * w * (1/T) * A * r * 1[u<=c]."""
    ratio = jnp.exp(new_lp_ref[...] - old_lp_ref[...])
    adv = adv_ref[...]
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    active = (unclipped <= clipped).astype(g_ref.dtype)
    d_new_lp_ref[...] = (-g_ref[...] * ht_w_ref[...] * inv_len_ref[...]
                         * adv * ratio * active * live_ref[...])


def _run_fwd(new_lp, old_lp, ht_w, live, adv, inv_len, clip_eps, bb, bt):
    b, t = new_lp.shape
    bb = min(bb, max(b, 1))
    bt = min(bt, max(t, 1))
    args = [_pad_bt(x, bb, bt) for x in (new_lp, old_lp, ht_w, live)]
    adv_p = _pad_b(adv, bb)[:, None]
    inv_p = _pad_b(inv_len, bb)[:, None]
    pb, ptt = args[0].shape
    tile2, col = _tile_specs(bb, bt)
    loss, clip_ind = pl.pallas_call(
        functools.partial(_fwd_kernel, clip_eps=clip_eps),
        grid=(pb // bb, ptt // bt),
        in_specs=[tile2, tile2, tile2, tile2, col, col],
        out_specs=[tile2, tile2],
        out_shape=[
            jax.ShapeDtypeStruct((pb, ptt), new_lp.dtype),
            jax.ShapeDtypeStruct((pb, ptt), new_lp.dtype),
        ],
        interpret=True,
    )(*args, adv_p, inv_p)
    return loss[:b, :t], clip_ind[:b, :t]


def _run_bwd(g, new_lp, old_lp, ht_w, live, adv, inv_len, clip_eps, bb, bt):
    b, t = new_lp.shape
    bb = min(bb, max(b, 1))
    bt = min(bt, max(t, 1))
    args = [_pad_bt(x, bb, bt) for x in (g, new_lp, old_lp, ht_w, live)]
    adv_p = _pad_b(adv, bb)[:, None]
    inv_p = _pad_b(inv_len, bb)[:, None]
    pb, ptt = args[0].shape
    tile2, col = _tile_specs(bb, bt)
    d_new = pl.pallas_call(
        functools.partial(_bwd_kernel, clip_eps=clip_eps),
        grid=(pb // bb, ptt // bt),
        in_specs=[tile2, tile2, tile2, tile2, tile2, col, col],
        out_specs=tile2,
        out_shape=jax.ShapeDtypeStruct((pb, ptt), new_lp.dtype),
        interpret=True,
    )(*args, adv_p, inv_p)
    return d_new[:b, :t]


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def compact_nat_loss(new_lp, old_lp, ht_w, live, adv, inv_len, clip_eps,
                     block_b=BLOCK_B, block_t=BLOCK_T):
    """Fused NAT loss on compacted [B, K] slots. Differentiable in ``new_lp``.

    ``live`` is the slot-validity mask (1.0 where gather >= 0, 0.0 on empty
    padding slots) as f32 — kept float so the custom_vjp signature stays
    all-float. Returns (loss_tok [B, K], clip_ind [B, K]).
    """
    return _run_fwd(new_lp, old_lp, ht_w, live, adv, inv_len, clip_eps,
                    block_b, block_t)


def _vjp_fwd(new_lp, old_lp, ht_w, live, adv, inv_len, clip_eps,
             block_b, block_t):
    out = _run_fwd(new_lp, old_lp, ht_w, live, adv, inv_len, clip_eps,
                   block_b, block_t)
    return out, (new_lp, old_lp, ht_w, live, adv, inv_len)


def _vjp_bwd(clip_eps, block_b, block_t, res, cts):
    new_lp, old_lp, ht_w, live, adv, inv_len = res
    g_loss, _g_clip = cts  # clip indicator is a non-differentiable statistic
    d_new = _run_bwd(g_loss, new_lp, old_lp, ht_w, live, adv, inv_len,
                     clip_eps, block_b, block_t)
    zeros_like = jnp.zeros_like
    return (d_new, zeros_like(old_lp), zeros_like(ht_w), zeros_like(live),
            zeros_like(adv), zeros_like(inv_len))


compact_nat_loss.defvjp(_vjp_fwd, _vjp_bwd)
