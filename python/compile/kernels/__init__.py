# L1: Pallas kernels for the paper's compute hot-spots — fused NAT loss
# (nat_loss), flash attention (attention), and the gather-compacted
# kept-token layout (compact: gather/scatter transforms + compacted loss).
