"""L1 Pallas kernel: blocked causal flash-attention with left-pad masking.

TPU adaptation of the memory-bound attention forward used by the NAT scoring
path: queries are tiled into (BLOCK_Q) chunks held in VMEM; the key/value
stream is consumed in (BLOCK_K) chunks with an online-softmax running
(max, sum, acc) state, so the [S, S] score matrix is never materialised —
the TPU analogue of the threadblock streaming the paper's GPU baselines get
from fused attention kernels. Under RPC the scored sequence is the retained
prefix, so S itself shrinks; this kernel keeps the *within-S* memory flat.

Forward-only: it backs the AOT ``score`` artifact (logprob/entropy
diagnostics), which is never differentiated. interpret=True for CPU PJRT.
Oracle: kernels.ref.causal_attention_ref.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 64
BLOCK_K = 64

_NEG_INF = -1e30


def _attn_kernel(plen_ref, q_ref, k_ref, v_ref, o_ref, *, block_k, seq_len,
                 scale):
    """One (batch*head, q-block) program: stream K/V blocks with online softmax."""
    qi = pl.program_id(2)
    q = q_ref[...]  # [block_q, dh]
    block_q = q.shape[0]
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
    pad = plen_ref[0]

    m = jnp.full((block_q, 1), _NEG_INF, dtype=jnp.float32)
    l = jnp.zeros((block_q, 1), dtype=jnp.float32)
    acc = jnp.zeros((block_q, q.shape[1]), dtype=jnp.float32)

    num_k_blocks = seq_len // block_k

    def body(kb, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        k_pos = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        valid = jnp.logical_and(k_pos <= q_pos, k_pos >= pad)
        s = jnp.where(valid, s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_new = acc * alpha + jnp.dot(p, v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, num_k_blocks, body, (m, l, acc))
    # Queries inside the left pad have no valid keys; their masked scores are
    # uniformly -1e30, so acc/l would be a block-size-dependent mean of V.
    # Define their output as exactly zero instead.
    row_valid = (q_pos >= pad).astype(jnp.float32)
    safe_l = jnp.where(l > 0.0, l, 1.0)
    o_ref[...] = (row_valid * acc / safe_l).astype(o_ref.dtype)


def flash_attention(q, k, v, pad_len, block_q=BLOCK_Q, block_k=BLOCK_K):
    """Left-pad-aware causal attention. q, k, v: [B, H, S, Dh]; pad_len: [B]."""
    b, h, s, dh = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    # The padded length must be divisible by BOTH block sizes: the k-stream
    # loop runs sp // block_k iterations, so a remainder would drop keys.
    pad_s = (-s) % math.lcm(block_q, block_k)
    if pad_s:
        padcfg = ((0, 0), (0, 0), (0, pad_s), (0, 0))
        q = jnp.pad(q, padcfg)
        k = jnp.pad(k, padcfg)
        v = jnp.pad(v, padcfg)
    sp = q.shape[2]
    scale = 1.0 / float(dh) ** 0.5
    grid = (b, h, sp // block_q)
    out = pl.pallas_call(
        functools.partial(_attn_kernel, block_k=block_k, seq_len=sp,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, qi: (bi,)),
            pl.BlockSpec((None, None, block_q, dh),
                         lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((None, None, sp, dh), lambda bi, hi, qi: (bi, hi, 0, 0)),
            pl.BlockSpec((None, None, sp, dh), lambda bi, hi, qi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, dh),
                               lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sp, dh), q.dtype),
        interpret=True,
    )(pad_len.astype(jnp.int32), q, k, v)
    return out[:, :, :s, :]


def prefill_attention(q, k, v, pad_len):
    """Prompt-window attention for the split-rollout ``prefill`` artifact.

    Same blocked causal kernel, shaped to the prefill call: S here is the
    prompt window P (48–128 across the preset configs), so clamping both
    block sizes to S gives one q-block per (batch, head) program and a
    single-pass K/V stream — the whole window lives in VMEM at once, the
    online-softmax state never carries across blocks, and the lcm padding
    in ``flash_attention`` becomes a no-op. Forward-only, like ``score``:
    the prefill artifact is never differentiated. The default ``prefill``
    lowering uses the dense jnp attention (the bit-identity path shared
    with fused generate); this variant backs ``prefill_pallas.hlo.txt``,
    proving the L1 kernel composes with the split rollout under rust PJRT.
    """
    s = q.shape[2]
    return flash_attention(q, k, v, pad_len, block_q=s, block_k=s)
