"""L2 model invariants: shapes, causality, padding, rollout consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.PRESETS["tiny"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


def _prompts(b, seed=0):
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(1, CFG.vocab, (b, CFG.prompt_len)),
                          dtype=jnp.int32)
    plen = jnp.asarray(rng.integers(1, CFG.prompt_len + 1, b), jnp.int32)
    pad = CFG.prompt_len - plen
    return prompts, pad


class TestForward:
    def test_logits_shape(self, params):
        prompts, pad = _prompts(3)
        logits = M.forward(CFG, params, prompts, pad)
        assert logits.shape == (3, CFG.prompt_len, CFG.vocab)

    def test_causality(self, params):
        """Changing a future token must not change earlier REAL logits.

        Positions inside the left pad have no valid keys (their attention
        output is an undefined uniform average) and are never read by any
        consumer; causality is asserted on real positions only.
        """
        prompts, pad = _prompts(2, seed=1)
        l1 = M.forward(CFG, params, prompts, pad)
        mod = prompts.at[:, -1].set((prompts[:, -1] + 1) % CFG.vocab)
        l2 = M.forward(CFG, params, mod, pad)
        d = np.abs(np.asarray(l1) - np.asarray(l2)).max(axis=2)
        for b in range(2):
            real = slice(int(pad[b]), CFG.prompt_len - 1)
            assert d[b, real].max() < 1e-5
        assert not np.allclose(l1[:, -1], l2[:, -1])

    def test_pad_content_invariance(self, params):
        """Tokens inside the left pad must not influence any real position."""
        prompts, _ = _prompts(2, seed=2)
        pad = jnp.asarray([7, 3], jnp.int32)
        altered = prompts.at[0, :7].set(5).at[1, :3].set(9)
        l1 = M.forward(CFG, params, prompts, pad)
        l2 = M.forward(CFG, params, altered, pad)
        # positions >= pad are real; embeddings at pad positions differ but
        # must not leak through attention into real positions
        np.testing.assert_allclose(l1[0, 7:], l2[0, 7:], rtol=2e-5, atol=1e-5)
        np.testing.assert_allclose(l1[1, 3:], l2[1, 3:], rtol=2e-5, atol=1e-5)

    def test_pallas_attention_path_matches_dense(self, params):
        prompts, pad = _prompts(2, seed=3)
        l_dense = M.forward(CFG, params, prompts, pad, use_pallas_attn=False)
        l_pallas = M.forward(CFG, params, prompts, pad, use_pallas_attn=True)
        valid = (np.arange(CFG.prompt_len)[None, :] >= np.asarray(pad)[:, None])
        m = valid[:, :, None]
        np.testing.assert_allclose(np.where(m, np.asarray(l_dense), 0),
                                   np.where(m, np.asarray(l_pallas), 0),
                                   rtol=2e-4, atol=2e-4)


class TestGenerate:
    def test_shapes_and_prompt_preserved(self, params):
        prompts, pad = _prompts(CFG.batch_rollout, seed=4)
        toks, lps = M.generate(CFG, params, prompts, pad,
                               jnp.int32(1), jnp.float32(1.0))
        assert toks.shape == (CFG.batch_rollout, CFG.seq_total)
        assert lps.shape == (CFG.batch_rollout, CFG.max_resp)
        np.testing.assert_array_equal(toks[:, :CFG.prompt_len], prompts)

    def test_deterministic_per_seed(self, params):
        prompts, pad = _prompts(4, seed=5)
        t1, l1 = M.generate(CFG, params, prompts, pad, jnp.int32(9),
                            jnp.float32(1.0))
        t2, l2 = M.generate(CFG, params, prompts, pad, jnp.int32(9),
                            jnp.float32(1.0))
        np.testing.assert_array_equal(t1, t2)
        np.testing.assert_allclose(l1, l2)
        t3, _ = M.generate(CFG, params, prompts, pad, jnp.int32(10),
                           jnp.float32(1.0))
        assert not np.array_equal(np.asarray(t1), np.asarray(t3))

    def test_score_reproduces_behaviour_logprobs(self, params):
        """THE consistency contract: learner-side scoring of rollout tokens
        must reproduce the rollout's own logprobs (ratio == 1 on-policy).
        Compared per row up to its EOS: the early-exit decode stops the
        whole batch once every row has finished, so positions past the stop
        point are unfilled (lp 0) and are never consumed by the learner."""
        P = CFG.prompt_len
        prompts, pad = _prompts(4, seed=6)
        toks, lps = M.generate(CFG, params, prompts, pad, jnp.int32(3),
                               jnp.float32(1.0))
        lp2, ent = M.score(CFG, params, toks, pad, CFG.max_resp)
        for i, row in enumerate(np.asarray(toks)[:, P:]):
            eos = np.flatnonzero(row == CFG.eos_id)
            n = int(eos[0]) + 1 if eos.size else row.shape[0]
            np.testing.assert_allclose(np.asarray(lps)[i, :n],
                                       np.asarray(lp2)[i, :n],
                                       rtol=5e-4, atol=5e-5)
        assert np.all(np.asarray(ent) >= 0)

    def test_per_row_seeds_are_batch_and_cap_invariant(self, params):
        """The bucketed scheduler's contract: with per-row seeds, a row's
        sampled stream UP TO ITS OWN EOS depends only on its (prompt, seed)
        — shuffling rows, and capping the window at a bucket, reproduce the
        same response prefix and logprobs. (Positions past a row's EOS keep
        sampling until the whole batch stops, so they are batch-dependent;
        the Rust scheduler blanks them to PAD.)"""
        P = CFG.prompt_len

        def resp_lens(toks):
            out = []
            for row in np.asarray(toks)[:, P:]:
                eos = np.flatnonzero(row == CFG.eos_id)
                out.append(int(eos[0]) + 1 if eos.size else row.shape[0])
            return out

        prompts, pad = _prompts(4, seed=8)
        seeds = jnp.arange(11, 15, dtype=jnp.int32)
        t1, l1 = M.generate(CFG, params, prompts, pad, seeds,
                            jnp.float32(1.0))
        lens = resp_lens(t1)
        # reversed batch order: row i's stream must follow its seed
        rev = np.arange(3, -1, -1)
        t2, l2 = M.generate(CFG, params, prompts[rev], pad[rev], seeds[rev],
                            jnp.float32(1.0))
        assert resp_lens(t2) == [lens[i] for i in rev]
        for i, n in enumerate(lens):
            np.testing.assert_array_equal(
                np.asarray(t1)[i, P:P + n], np.asarray(t2)[rev][i, P:P + n])
            # reordering the batch reorders XLA reductions: allow a few
            # ulps of float32 slack instead of the exact-match default
            np.testing.assert_allclose(
                np.asarray(l1)[i, :n], np.asarray(l2)[rev][i, :n],
                rtol=1e-6, atol=1e-7)
        # a shorter bucket cap yields the identical per-row prefix
        cap = CFG.buckets[0]
        t3, l3 = M.generate(CFG, params, prompts, pad, seeds,
                            jnp.float32(1.0), t_max=cap)
        for i, n in enumerate(min(n, cap) for n in lens):
            np.testing.assert_array_equal(
                np.asarray(t1)[i, P:P + n], np.asarray(t3)[i, P:P + n])
            np.testing.assert_allclose(
                np.asarray(l1)[i, :n], np.asarray(l3)[i, :n],
                rtol=1e-6, atol=1e-7)

    def test_low_temperature_is_greedy(self, params):
        prompts, pad = _prompts(3, seed=7)
        t1, _ = M.generate(CFG, params, prompts, pad, jnp.int32(0),
                           jnp.float32(1e-4))
        t2, _ = M.generate(CFG, params, prompts, pad, jnp.int32(99),
                           jnp.float32(1e-4))
        np.testing.assert_array_equal(t1, t2)  # seed-independent at temp->0


class TestPrefillDecodeSplit:
    """The split-rollout artifacts' contract with the fused generate."""

    def test_flat_blocks_decode_bit_identical_to_generate(self, params):
        """Per-prompt B=1 prefill rows, concatenated and decoded as a
        batch, must reproduce the fused batch generate exactly — the
        determinism contract the Rust shared-prefix cache rides on (cache
        on/off can change cost, never output). This mirrors the artifact
        path end to end: ``Runtime::prefill`` runs the B=1 prefill per
        cache miss; ``generate_bucketed_kv`` concatenates the cached rows
        and drives ``decode_T<b>``."""
        B, P = CFG.batch_rollout, CFG.prompt_len
        prompts, pad = _prompts(B, seed=11)
        seeds = jnp.arange(21, 21 + B, dtype=jnp.int32)
        cap = CFG.buckets[0]
        rows = [M.prefill_flat(CFG, params, prompts[i:i + 1], pad[i:i + 1])
                for i in range(B)]
        kv_flat = jnp.concatenate(rows, axis=0)
        t1, l1 = M.decode_from_flat_kv(CFG, params, prompts, pad, kv_flat,
                                       seeds, jnp.float32(1.0), cap)
        t2, l2 = M.generate(CFG, params, prompts, pad, seeds,
                            jnp.float32(1.0), t_max=cap)
        np.testing.assert_array_equal(t1, t2)
        np.testing.assert_allclose(l1, l2, rtol=1e-6, atol=1e-7)

    def test_kv_flatten_split_roundtrip(self, params):
        prompts, pad = _prompts(2, seed=12)
        out = M.prefill(CFG, params, prompts, pad)
        flat = M.kv_flatten(CFG, out)
        assert flat.shape == (2, M.kv_flat_width(CFG))
        ks, vs, logits0 = M.kv_split(CFG, CFG.prompt_len, flat)
        L = CFG.n_layers
        for a, b in zip(ks, out[:L]):
            np.testing.assert_array_equal(a, b)
        for a, b in zip(vs, out[L:2 * L]):
            np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(logits0, out[2 * L])

    def test_pallas_prefill_matches_dense(self, params):
        """The prefill_pallas variant, like score_pallas: kernel-tolerance
        agreement with the dense path on REAL positions (pad-position K/V
        are never attended to — valid keys satisfy k_pos >= pad)."""
        prompts, pad = _prompts(2, seed=13)
        dense = M.prefill(CFG, params, prompts, pad)
        pallas = M.prefill(CFG, params, prompts, pad, use_pallas_attn=True)
        L = CFG.n_layers
        valid = (np.arange(CFG.prompt_len)[None, :]
                 >= np.asarray(pad)[:, None])
        m = valid[:, None, :, None]  # broadcast over [B, H, P, Hd]
        for a, b in zip(pallas[:2 * L], dense[:2 * L]):
            np.testing.assert_allclose(np.where(m, np.asarray(a), 0),
                                       np.where(m, np.asarray(b), 0),
                                       rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(pallas[2 * L], dense[2 * L],
                                   rtol=2e-4, atol=2e-4)


class TestNatGrad:
    def _grad_inputs(self, bucket, seed=0):
        rng = np.random.default_rng(seed)
        B = CFG.batch_train
        S = CFG.prompt_len + bucket
        tokens = jnp.asarray(rng.integers(1, CFG.vocab, (B, S)), jnp.int32)
        ht_w = jnp.asarray(rng.random((B, bucket)).astype(np.float32))
        adv = jnp.asarray(rng.normal(0, 1, B).astype(np.float32))
        old_lp = jnp.asarray(rng.normal(-3, 0.5, (B, bucket)).astype(np.float32))
        inv_len = jnp.full((B,), 1.0 / bucket, jnp.float32)
        pad = jnp.zeros((B,), jnp.int32)
        return tokens, ht_w, adv, old_lp, inv_len, pad

    def test_shapes(self, params):
        bucket = CFG.buckets[0]
        outs = M.nat_grad(CFG, params, *self._grad_inputs(bucket), bucket)
        assert len(outs) == len(params) + 1
        for g, p in zip(outs[:-1], params):
            assert g.shape == p.shape
        assert outs[-1].shape == (5,)

    def test_zero_weights_give_zero_grads(self, params):
        bucket = CFG.buckets[0]
        tokens, ht_w, adv, old_lp, inv_len, pad = self._grad_inputs(bucket)
        outs = M.nat_grad(CFG, params, tokens, jnp.zeros_like(ht_w), adv,
                          old_lp, inv_len, pad, bucket)
        for g in outs[:-1]:
            np.testing.assert_allclose(g, np.zeros(g.shape), atol=1e-8)

    def test_grad_matches_direct_autodiff(self, params):
        """Pallas-kernel gradient path == jnp reference loss gradient."""
        from compile.kernels import ref as kref
        bucket = CFG.buckets[0]
        args = self._grad_inputs(bucket, seed=3)
        tokens, ht_w, adv, old_lp, inv_len, pad = args

        def ref_loss(ps):
            logits = M.forward(CFG, ps, tokens, pad)
            new_lp, _ = M._resp_logprobs(CFG, logits, tokens, bucket)
            lt, _ = kref.nat_loss_tokens_ref(new_lp, old_lp, ht_w, adv,
                                             inv_len, CFG.clip_eps)
            return jnp.sum(lt)

        want = jax.grad(ref_loss)(list(params))
        got = M.nat_grad(CFG, params, *args, bucket)[:-1]
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=2e-3, atol=1e-6)


class TestNatGradCompact:
    """The gather-compacted learner (grad_K<k>_B<r> family)."""

    def _compact_inputs(self, k, seed=0, kept=None):
        """A compacted micro-batch: ``kept`` live slots per row (rest -1)."""
        rng = np.random.default_rng(seed)
        B = CFG.batch_train
        S = CFG.prompt_len + k
        kept = k if kept is None else kept
        tokens = jnp.asarray(rng.integers(1, CFG.vocab, (B, S)), jnp.int32)
        ht_w = jnp.asarray(rng.uniform(0.5, 2.0, (B, k)).astype(np.float32))
        adv = jnp.asarray(rng.normal(0, 1, B).astype(np.float32))
        old_lp = jnp.asarray(rng.normal(-3, 0.5, (B, k)).astype(np.float32))
        inv_len = jnp.full((B,), 1.0 / k, jnp.float32)
        pad = jnp.zeros((B,), jnp.int32)
        # scattered ascending original positions out of a 2x response window
        gather = np.full((B, k), -1, np.int32)
        for i in range(B):
            gather[i, :kept] = np.sort(
                rng.choice(2 * k, size=kept, replace=False)).astype(np.int32)
        if kept < k:
            ht_w = ht_w * (jnp.asarray(gather) >= 0)
        return tokens, ht_w, adv, old_lp, inv_len, pad, jnp.asarray(gather)

    def test_shapes(self, params):
        k = CFG.buckets[0]
        outs = M.nat_grad_compact(CFG, params, *self._compact_inputs(k), k)
        assert len(outs) == len(params) + 1
        for g, p in zip(outs[:-1], params):
            assert g.shape == p.shape
        assert outs[-1].shape == (5,)

    def test_identity_gather_matches_nat_grad(self, params):
        """A fully-kept row set with gather == [0..k) is exactly the legacy
        layout: same positions, same mask, same loss — the python mirror of
        the batcher's routes-to-legacy rule for prefix-shaped plans."""
        k = CFG.buckets[0]
        tokens, ht_w, adv, old_lp, inv_len, pad, _ = self._compact_inputs(
            k, seed=3)
        gather = jnp.asarray(np.tile(np.arange(k, dtype=np.int32),
                                     (CFG.batch_train, 1)))
        got = M.nat_grad_compact(CFG, params, tokens, ht_w, adv, old_lp,
                                 inv_len, pad, gather, k)
        want = M.nat_grad(CFG, params, tokens, ht_w, adv, old_lp, inv_len,
                          pad, k)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=2e-4, atol=1e-7)

    def test_empty_slot_content_is_inert(self, params):
        """Token values in dead (gather < 0) slots must not change any
        gradient or metric — the key_valid attention mask plus the live
        loss mask together guarantee the padding region is unobservable."""
        k = CFG.buckets[0]
        kept = k // 2
        tokens, ht_w, adv, old_lp, inv_len, pad, gather = \
            self._compact_inputs(k, seed=5, kept=kept)
        o1 = M.nat_grad_compact(CFG, params, tokens, ht_w, adv, old_lp,
                                inv_len, pad, gather, k)
        P = CFG.prompt_len
        mangled = tokens.at[:, P + kept:].set(
            (tokens[:, P + kept:] + 7) % CFG.vocab)
        o2 = M.nat_grad_compact(CFG, params, mangled, ht_w, adv, old_lp,
                                inv_len, pad, gather, k)
        for a, b in zip(o1, o2):
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)

    def test_zero_weights_give_zero_grads(self, params):
        k = CFG.buckets[0]
        tokens, ht_w, adv, old_lp, inv_len, pad, gather = \
            self._compact_inputs(k, seed=7)
        outs = M.nat_grad_compact(CFG, params, tokens, jnp.zeros_like(ht_w),
                                  adv, old_lp, inv_len, pad, gather, k)
        for g in outs[:-1]:
            np.testing.assert_allclose(g, np.zeros(g.shape), atol=1e-8)

    def test_kept_tokens_use_original_rope_positions(self, params):
        """The same kept slots with different original positions must score
        differently: position identity comes from the gather list, not the
        compacted slot index."""
        k = CFG.buckets[0]
        kept = k // 2
        tokens, ht_w, adv, old_lp, inv_len, pad, gather = \
            self._compact_inputs(k, seed=9, kept=kept)
        l1 = M.forward_compact(CFG, params, tokens, gather, pad)
        shifted = jnp.where(gather >= 0, gather + 3, gather)
        l2 = M.forward_compact(CFG, params, tokens, shifted, pad)
        P = CFG.prompt_len
        assert float(jnp.max(jnp.abs(
            l1[:, P:P + kept] - l2[:, P:P + kept]))) > 1e-4


class TestOptimisers:
    def test_adamw_apply_moves_params(self, params):
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        grads = [jnp.ones_like(p) * 0.01 for p in params]
        outs = M.adamw_apply(CFG, params, m, v, jnp.float32(1.0), grads,
                             jnp.float32(0.5))
        n = len(params)
        new_p = outs[:n]
        gnorm = outs[-1]
        assert gnorm.shape == (1,)
        moved = sum(float(jnp.max(jnp.abs(a - b))) for a, b in
                    zip(new_p, params))
        assert moved > 0

    def test_grad_clip_bounds_update(self, params):
        """A huge gradient must produce the same update as a scaled one."""
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        g1 = [jnp.ones_like(p) * 1e3 for p in params]
        g2 = [jnp.ones_like(p) * 1e6 for p in params]
        o1 = M.adamw_apply(CFG, params, m, v, jnp.float32(1.0), g1,
                           jnp.float32(1.0))
        o2 = M.adamw_apply(CFG, params, m, v, jnp.float32(1.0), g2,
                           jnp.float32(1.0))
        n = len(params)
        for a, b in zip(o1[:n], o2[:n]):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)

    def test_pretrain_step_reduces_loss(self, params):
        rng = np.random.default_rng(0)
        B, S = CFG.batch_pretrain, CFG.pretrain_len
        # a trivially learnable corpus: constant token sequences
        tokens = jnp.asarray(np.tile(rng.integers(1, 8, (1, S)), (B, 1)),
                             jnp.int32)
        mask = jnp.ones((B, S - 1), jnp.float32)
        p = [jnp.asarray(x) for x in params]
        m = [jnp.zeros_like(x) for x in p]
        v = [jnp.zeros_like(x) for x in p]
        n = len(p)
        losses = []
        pad0 = jnp.zeros((B,), jnp.int32)
        for step in range(8):
            outs = M.pretrain_step(CFG, p, m, v, jnp.float32(step + 1),
                                   tokens, mask, pad0)
            p = list(outs[:n])
            m = list(outs[n:2 * n])
            v = list(outs[2 * n:3 * n])
            losses.append(float(outs[-1][0]))
        assert losses[-1] < losses[0] * 0.8, losses
