"""AOT pipeline: lowering, manifest consistency, HLO-text executability."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot
from compile import model as M

CFG = M.PRESETS["tiny"]


def _entry_param_count(text: str) -> int:
    """Count parameters of the ENTRY computation only (nested computations
    in HLO text also contain parameter() instructions)."""
    start = text.index("ENTRY")
    depth = 0
    end = start
    for i, ch in enumerate(text[start:], start):
        if ch == "{":
            depth += 1
        elif ch == "}":
            depth -= 1
            if depth == 0:
                end = i
                break
    return text[start:end].count(" parameter(")


class TestLowering:
    def test_all_artifacts_lower_and_contain_entry(self):
        for name, lowered in [
            ("generate", aot.lower_generate(CFG)),
            ("generate_bucket", aot.lower_generate_bucket(CFG, CFG.buckets[0])),
            ("prefill", aot.lower_prefill(CFG)),
            ("decode_bucket", aot.lower_decode_bucket(CFG, CFG.buckets[0])),
            ("score", aot.lower_score(CFG, CFG.buckets[-1])),
            ("grad", aot.lower_grad(CFG, CFG.buckets[0])),
            ("grad_compact", aot.lower_grad_compact(CFG, CFG.buckets[0])),
            ("apply", aot.lower_apply(CFG)),
            ("pretrain", aot.lower_pretrain(CFG)),
        ]:
            text = aot.to_hlo_text(lowered)
            assert "ENTRY" in text, name
            assert "HloModule" in text, name

    def test_grad_artifact_parameter_count(self):
        """Input arity contract with the Rust runtime."""
        lowered = aot.lower_grad(CFG, CFG.buckets[0])
        text = aot.to_hlo_text(lowered)
        n_params = len(M.param_spec(CFG))
        count = _entry_param_count(text)
        assert count == n_params + 6, (count, n_params)

    def test_grad_compact_artifact_parameter_count(self):
        """Legacy grad arity + 1: the trailing [B, K] int32 gather operand."""
        lowered = aot.lower_grad_compact(CFG, CFG.buckets[0], rows=1)
        text = aot.to_hlo_text(lowered)
        n_params = len(M.param_spec(CFG))
        count = _entry_param_count(text)
        assert count == n_params + 7, (count, n_params)

    def test_apply_artifact_parameter_count(self):
        lowered = aot.lower_apply(CFG)
        text = aot.to_hlo_text(lowered)
        n = len(M.param_spec(CFG))
        assert _entry_param_count(text) == 4 * n + 2

    def test_prefill_artifact_parameter_count(self):
        """params + (prompt [1,P], pad [1]): the per-prompt B=1 ABI
        ``Runtime::prefill`` drives once per cache miss."""
        text = aot.to_hlo_text(aot.lower_prefill(CFG))
        assert _entry_param_count(text) == len(M.param_spec(CFG)) + 2

    def test_decode_artifact_parameter_count(self):
        """params + (prompts, pads, kv, seeds, temp): generate_bucket's
        arity + 1 for the flat KV matrix ``generate_bucketed_kv`` sends."""
        text = aot.to_hlo_text(aot.lower_decode_bucket(CFG, CFG.buckets[0]))
        assert _entry_param_count(text) == len(M.param_spec(CFG)) + 5


class TestManifest:
    def test_offsets_are_contiguous(self):
        man = aot.build_manifest(CFG)
        off = 0
        for p in man["params"]:
            assert p["offset"] == off
            assert p["size"] == int(np.prod(p["shape"]))
            off += p["size"]
        assert man["param_count"] == off == M.param_count(CFG)

    def test_manifest_matches_spec(self):
        man = aot.build_manifest(CFG)
        spec = M.param_spec(CFG)
        assert len(man["params"]) == len(spec)
        for entry, (name, shape) in zip(man["params"], spec):
            assert entry["name"] == name
            assert tuple(entry["shape"]) == tuple(shape)

    def test_grad_buckets_cover_config(self):
        man = aot.build_manifest(CFG)
        assert sorted(int(b) for b in man["artifacts"]["grad"]) == \
            sorted(CFG.buckets)

    def test_grad_row_grid_covers_every_bucket(self):
        man = aot.build_manifest(CFG)
        grid = aot.row_grid(CFG.batch_train)
        assert grid == sorted(grid)
        assert all(r < CFG.batch_train for r in grid)
        keys = set(man["artifacts"]["grad_rows"])
        assert keys == {f"{b}x{r}" for b in CFG.buckets for r in grid}

    def test_grad_compact_grid_covers_every_cell(self):
        """Every (kept bucket, rows) cell is explicit — the compact family
        has no legacy full-row artifact to fall back on, so the row axis
        includes batch_train itself."""
        man = aot.build_manifest(CFG)
        rows = aot.row_grid(CFG.batch_train) + [CFG.batch_train]
        keys = set(man["artifacts"]["grad_compact"])
        assert keys == {f"{k}x{r}" for k in CFG.buckets for r in rows}
        assert man["artifacts"]["grad_compact"][
            f"{CFG.buckets[0]}x{CFG.batch_train}"] == \
            f"grad_K{CFG.buckets[0]}_B{CFG.batch_train}.hlo.txt"

    def test_row_grid_is_powers_of_two(self):
        assert aot.row_grid(8) == [1, 2, 4]
        assert aot.row_grid(6) == [1, 2, 4]
        assert aot.row_grid(1) == []

    def test_generate_buckets_cover_config(self):
        man = aot.build_manifest(CFG)
        gb = man["artifacts"]["generate_buckets"]
        assert sorted(int(b) for b in gb) == sorted(CFG.buckets)
        # the top bucket (== max_resp) must be present: the scheduler's
        # escalation chain terminates there
        assert str(CFG.max_resp) in gb
        assert gb[str(CFG.max_resp)] == f"generate_T{CFG.max_resp}.hlo.txt"

    def test_prefill_decode_split_is_paired_and_covers_buckets(self):
        """Mirrors the Rust manifest validation: prefill and decode_buckets
        present together, decode keys == config buckets (top included)."""
        man = aot.build_manifest(CFG)
        arts = man["artifacts"]
        assert arts["prefill"] == "prefill.hlo.txt"
        db = arts["decode_buckets"]
        assert sorted(int(b) for b in db) == sorted(CFG.buckets)
        assert db[str(CFG.max_resp)] == f"decode_T{CFG.max_resp}.hlo.txt"


class TestBuiltArtifacts:
    """Validate the on-disk artifact set if `make artifacts` has run."""

    ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                       "tiny")

    @pytest.fixture(autouse=True)
    def _skip_if_missing(self):
        if not os.path.exists(os.path.join(self.ART, "manifest.json")):
            pytest.skip("artifacts/tiny not built")

    def test_init_params_size_matches_manifest(self):
        man = json.load(open(os.path.join(self.ART, "manifest.json")))
        raw = os.path.getsize(os.path.join(self.ART, "init_params.bin"))
        assert raw == man["param_count"] * 4

    def test_all_listed_artifacts_exist(self):
        man = json.load(open(os.path.join(self.ART, "manifest.json")))
        arts = man["artifacts"]
        files = [arts["generate"], arts["apply"], arts["pretrain"]]
        files += list(arts["grad"].values()) + list(arts["score"].values())
        files += list(arts.get("grad_rows", {}).values())
        files += list(arts.get("grad_compact", {}).values())
        # the split family (absent from manifests built before it existed)
        files += list(arts.get("decode_buckets", {}).values())
        if "prefill" in arts:
            files.append(arts["prefill"])
        for f in files:
            path = os.path.join(self.ART, f)
            assert os.path.exists(path), f
            with open(path) as fh:
                assert "ENTRY" in fh.read()
