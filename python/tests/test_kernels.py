"""L1 kernel correctness: Pallas vs pure-jnp oracle (hypothesis sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, compact, nat_loss, ref

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def _case(seed, b, t, adv_scale=1.0):
    rng = np.random.default_rng(seed)
    new_lp = jnp.asarray(rng.normal(-2.0, 0.7, (b, t)).astype(np.float32))
    old_lp = new_lp + jnp.asarray(rng.normal(0, 0.3, (b, t)).astype(np.float32))
    keep = rng.random((b, t)) < 0.6
    p_inc = rng.uniform(0.2, 1.0, (b, t)).astype(np.float32)
    ht_w = jnp.asarray(np.where(keep, 1.0 / p_inc, 0.0).astype(np.float32))
    adv = jnp.asarray((adv_scale * rng.normal(0, 1, b)).astype(np.float32))
    inv_len = jnp.asarray(1.0 / rng.integers(1, t + 1, b).astype(np.float32))
    return new_lp, old_lp, ht_w, adv, inv_len


class TestNatLossForward:
    @given(seed=st.integers(0, 10_000), b=st.integers(1, 9),
           t=st.integers(1, 200))
    def test_matches_ref(self, seed, b, t):
        args = _case(seed, b, t)
        lt, ci = nat_loss.nat_loss_tokens(*args, 0.2)
        lt_r, ci_r = ref.nat_loss_tokens_ref(*args, 0.2)
        np.testing.assert_allclose(lt, lt_r, rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(ci, ci_r)

    @given(seed=st.integers(0, 10_000),
           clip_eps=st.floats(0.05, 0.5))
    def test_clip_eps_sweep(self, seed, clip_eps):
        args = _case(seed, 4, 33)
        lt, ci = nat_loss.nat_loss_tokens(*args, clip_eps)
        lt_r, ci_r = ref.nat_loss_tokens_ref(*args, clip_eps)
        np.testing.assert_allclose(lt, lt_r, rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(ci, ci_r)

    def test_excluded_tokens_contribute_zero(self):
        new_lp, old_lp, ht_w, adv, inv_len = _case(3, 4, 50)
        ht_w = ht_w.at[:, 10:].set(0.0)
        lt, _ = nat_loss.nat_loss_tokens(new_lp, old_lp, ht_w, adv, inv_len, 0.2)
        assert np.all(np.asarray(lt[:, 10:]) == 0.0)

    def test_block_shape_invariance(self):
        """Different tilings must produce identical numerics."""
        args = _case(11, 7, 97)
        base, _ = nat_loss.nat_loss_tokens(*args, 0.2)
        for bb, bt in [(1, 8), (2, 32), (8, 256), (4, 17)]:
            out, _ = nat_loss.nat_loss_tokens(*args, 0.2, bb, bt)
            np.testing.assert_allclose(out, base, rtol=1e-6)

    def test_identity_ratio_reduces_to_pg(self):
        """old == new => ratio 1, never clipped, loss = -w*A/T."""
        rng = np.random.default_rng(0)
        lp = jnp.asarray(rng.normal(-1, 0.5, (3, 20)).astype(np.float32))
        ht_w = jnp.ones((3, 20), jnp.float32) * 2.0
        adv = jnp.asarray([1.0, -0.5, 0.0], jnp.float32)
        inv_len = jnp.asarray([0.05, 0.05, 0.05], jnp.float32)
        lt, ci = nat_loss.nat_loss_tokens(lp, lp, ht_w, adv, inv_len, 0.2)
        np.testing.assert_allclose(
            lt, -2.0 * adv[:, None] * 0.05 * np.ones((3, 20)), rtol=1e-6)
        assert np.all(np.asarray(ci) == 0.0)


class TestNatLossBackward:
    @given(seed=st.integers(0, 10_000), b=st.integers(1, 6),
           t=st.integers(1, 150))
    def test_grad_matches_ref_autodiff(self, seed, b, t):
        new_lp, old_lp, ht_w, adv, inv_len = _case(seed, b, t)
        rng = np.random.default_rng(seed + 1)
        g = jnp.asarray(rng.normal(0, 1, (b, t)).astype(np.float32))

        def f(nl):
            return jnp.sum(nat_loss.nat_loss_tokens(
                nl, old_lp, ht_w, adv, inv_len, 0.2)[0] * g)

        def fr(nl):
            return jnp.sum(ref.nat_loss_tokens_ref(
                nl, old_lp, ht_w, adv, inv_len, 0.2)[0] * g)

        np.testing.assert_allclose(jax.grad(f)(new_lp), jax.grad(fr)(new_lp),
                                   rtol=1e-4, atol=1e-6)

    def test_grad_matches_analytic(self):
        new_lp, old_lp, ht_w, adv, inv_len = _case(5, 4, 64)
        g = jnp.ones((4, 64), jnp.float32)
        got = jax.grad(lambda nl: jnp.sum(nat_loss.nat_loss_tokens(
            nl, old_lp, ht_w, adv, inv_len, 0.2)[0]))(new_lp)
        want = ref.nat_loss_grad_ref(new_lp, old_lp, ht_w, adv, inv_len, 0.2, g)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)

    def test_clipped_tokens_have_zero_grad(self):
        """Push ratios far outside the trust region; gradient must vanish."""
        b, t = 2, 16
        old_lp = jnp.full((b, t), -3.0, jnp.float32)
        new_lp = jnp.full((b, t), -1.0, jnp.float32)  # ratio = e^2 >> 1.2
        ht_w = jnp.ones((b, t), jnp.float32)
        adv = jnp.asarray([1.0, 2.0], jnp.float32)  # positive adv + high ratio
        inv_len = jnp.full((b,), 1.0 / t, jnp.float32)
        got = jax.grad(lambda nl: jnp.sum(nat_loss.nat_loss_tokens(
            nl, old_lp, ht_w, adv, inv_len, 0.2)[0]))(new_lp)
        np.testing.assert_allclose(got, np.zeros((b, t)), atol=1e-8)


def _gather_of(ht_w):
    """Per-row ascending gather list over kept (ht_w > 0) positions, -1
    padded to the max kept count — the layout batcher::pack_one_compact
    builds."""
    mask = np.asarray(ht_w) > 0.0
    b = mask.shape[0]
    k = max(int(mask.sum(axis=1).max()), 1)
    gather = np.full((b, k), -1, np.int32)
    for i in range(b):
        idx = np.flatnonzero(mask[i])
        gather[i, :idx.size] = idx
    return jnp.asarray(gather)


class TestCompactLayout:
    """Gather/scatter transforms + the compacted NAT loss vs the full
    layout: compaction must commute with the (position-free) surrogate."""

    @given(seed=st.integers(0, 10_000), b=st.integers(1, 9),
           t=st.integers(1, 120))
    def test_gather_scatter_round_trip(self, seed, b, t):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(0, 1, (b, t)).astype(np.float32))
        keep = jnp.asarray((rng.random((b, t)) < 0.5).astype(np.float32))
        g = _gather_of(keep)
        y = compact.gather_rows(x, g)
        back = compact.scatter_rows(y, g, t)
        np.testing.assert_allclose(back, np.asarray(x) * np.asarray(keep))
        # and gathering the scatter reproduces the compacted rows exactly
        np.testing.assert_allclose(compact.gather_rows(back, g), y)

    def test_gather_matches_numpy_oracle(self):
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.normal(0, 1, (5, 40)).astype(np.float32))
        g = jnp.asarray([[0, 3, 39, -1], [1, 2, 4, 8],
                         [-1, -1, -1, -1], [5, 6, 7, -1], [0, -1, -1, -1]],
                        jnp.int32)
        got = np.asarray(compact.gather_rows(x, g))
        xn = np.asarray(x)
        for i in range(5):
            for j in range(4):
                want = xn[i, g[i, j]] if int(g[i, j]) >= 0 else 0.0
                assert got[i, j] == np.float32(want), (i, j)

    @given(seed=st.integers(0, 10_000), b=st.integers(1, 6),
           t=st.integers(1, 150))
    def test_compact_loss_commutes_with_gather(self, seed, b, t):
        """Loss on gathered rows == gathered loss on full rows (non-kept
        full positions carry ht_w == 0, so their loss is already 0)."""
        new_lp, old_lp, ht_w, adv, inv_len = _case(seed, b, t)
        lt, ci = nat_loss.nat_loss_tokens(new_lp, old_lp, ht_w, adv,
                                          inv_len, 0.2)
        g = _gather_of(ht_w)
        nl_c, ol_c, hw_c = (compact.gather_rows(x, g)
                            for x in (new_lp, old_lp, ht_w))
        live = (g >= 0).astype(jnp.float32)
        lt_c, ci_c = compact.compact_nat_loss(nl_c, ol_c, hw_c, live, adv,
                                              inv_len, 0.2)
        kept = np.asarray(ht_w) > 0.0
        np.testing.assert_allclose(compact.scatter_rows(lt_c, g, t),
                                   np.asarray(lt) * kept,
                                   rtol=1e-6, atol=1e-7)
        # clip indicator: the full kernel reports it on every token; the
        # compacted one only carries kept slots
        np.testing.assert_allclose(compact.scatter_rows(ci_c, g, t),
                                   np.asarray(ci) * kept)

    @given(seed=st.integers(0, 10_000), b=st.integers(1, 5),
           t=st.integers(1, 100))
    def test_grad_scatters_back_to_masked_full_grad(self, seed, b, t):
        """d(compact loss)/d new_lp, scattered by position, == the kept-
        masked full-layout gradient — the round-trip contract that makes
        grad_K and grad_T artifacts interchangeable on kept tokens."""
        new_lp, old_lp, ht_w, adv, inv_len = _case(seed, b, t)
        g = _gather_of(ht_w)
        nl_c, ol_c, hw_c = (compact.gather_rows(x, g)
                            for x in (new_lp, old_lp, ht_w))
        live = (g >= 0).astype(jnp.float32)

        d_full = jax.grad(lambda nl: jnp.sum(nat_loss.nat_loss_tokens(
            nl, old_lp, ht_w, adv, inv_len, 0.2)[0]))(new_lp)
        d_c = jax.grad(lambda nl: jnp.sum(compact.compact_nat_loss(
            nl, ol_c, hw_c, live, adv, inv_len, 0.2)[0]))(nl_c)
        np.testing.assert_allclose(compact.scatter_rows(d_c, g, t),
                                   np.asarray(d_full),
                                   rtol=1e-5, atol=1e-7)

    def test_empty_slots_contribute_nothing(self):
        """Garbage values in dead (gather < 0) slots must not reach the
        loss, the clip statistic, or the gradient."""
        b, k = 3, 12
        rng = np.random.default_rng(0)
        nl = jnp.asarray(rng.normal(-2, 1, (b, k)).astype(np.float32))
        ol = jnp.asarray(rng.normal(-2, 1, (b, k)).astype(np.float32))
        hw = jnp.asarray(rng.uniform(1, 3, (b, k)).astype(np.float32))
        adv = jnp.asarray([1.0, -2.0, 0.5], jnp.float32)
        inv_len = jnp.full((b,), 0.1, jnp.float32)
        g = jnp.asarray(np.tile(np.arange(k, dtype=np.int32), (b, 1)))
        g = g.at[:, 5:].set(-1)  # trailing empty slots, packer-shaped
        live = (g >= 0).astype(jnp.float32)
        lt, ci = compact.compact_nat_loss(nl, ol, hw, live, adv, inv_len, 0.2)
        assert np.all(np.asarray(lt)[:, 5:] == 0.0)
        assert np.all(np.asarray(ci)[:, 5:] == 0.0)
        d = jax.grad(lambda x: jnp.sum(compact.compact_nat_loss(
            x, ol, hw, live, adv, inv_len, 0.2)[0]))(nl)
        assert np.all(np.asarray(d)[:, 5:] == 0.0)
        assert np.any(np.asarray(d)[:, :5] != 0.0)

    def test_full_keep_matches_nat_loss_exactly(self):
        """With every slot live the compacted kernel IS nat_loss."""
        args = _case(13, 6, 64)
        new_lp, old_lp, ht_w, adv, inv_len = args
        live = jnp.ones_like(ht_w)
        lt, ci = nat_loss.nat_loss_tokens(*args, 0.2)
        lt_c, ci_c = compact.compact_nat_loss(new_lp, old_lp, ht_w, live,
                                              adv, inv_len, 0.2)
        np.testing.assert_allclose(lt_c, lt, rtol=1e-7)
        np.testing.assert_allclose(ci_c, ci)


class TestFlashAttention:
    @given(seed=st.integers(0, 10_000), b=st.integers(1, 3),
           h=st.integers(1, 4), s=st.integers(2, 80),
           dh=st.sampled_from([4, 8, 16]))
    def test_matches_ref(self, seed, b, h, s, dh):
        rng = np.random.default_rng(seed)
        q, k, v = (jnp.asarray(rng.normal(0, 1, (b, h, s, dh))
                               .astype(np.float32)) for _ in range(3))
        pad = jnp.asarray(rng.integers(0, s // 2 + 1, b), dtype=jnp.int32)
        o = attention.flash_attention(q, k, v, pad, block_q=16, block_k=16)
        o_r = ref.causal_attention_ref(q, k, v, pad)
        valid = (np.arange(s)[None, :] >= np.asarray(pad)[:, None])
        m = valid[:, None, :, None]
        np.testing.assert_allclose(np.where(m, np.asarray(o), 0),
                                   np.where(m, np.asarray(o_r), 0),
                                   rtol=3e-5, atol=3e-5)

    def test_block_shape_invariance(self):
        rng = np.random.default_rng(1)
        q, k, v = (jnp.asarray(rng.normal(0, 1, (2, 2, 40, 8))
                               .astype(np.float32)) for _ in range(3))
        pad = jnp.asarray([0, 5], dtype=jnp.int32)
        base = attention.flash_attention(q, k, v, pad, block_q=8, block_k=8)
        for bq, bk in [(16, 8), (8, 16), (40, 40), (64, 32)]:
            o = attention.flash_attention(q, k, v, pad, block_q=bq, block_k=bk)
            np.testing.assert_allclose(o, base, rtol=2e-5, atol=2e-5)

    def test_causality(self):
        """Perturbing a future key/value must not change earlier outputs."""
        rng = np.random.default_rng(2)
        q, k, v = (jnp.asarray(rng.normal(0, 1, (1, 2, 32, 8))
                               .astype(np.float32)) for _ in range(3))
        pad = jnp.zeros((1,), jnp.int32)
        o1 = attention.flash_attention(q, k, v, pad, block_q=8, block_k=8)
        k2 = k.at[:, :, 20:, :].add(100.0)
        v2 = v.at[:, :, 20:, :].add(-50.0)
        o2 = attention.flash_attention(q, k2, v2, pad, block_q=8, block_k=8)
        np.testing.assert_allclose(o1[:, :, :20], o2[:, :, :20],
                                   rtol=1e-6, atol=1e-6)
