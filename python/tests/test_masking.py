"""Properties of the reference NAT samplers (mirrors rust proptests)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import masking_ref as mk

settings.register_profile("mask", max_examples=50, deadline=None)
settings.load_profile("mask")


@given(t=st.integers(1, 300), c=st.integers(1, 300))
def test_rpc_survival_properties(t, c):
    p = mk.rpc_survival(t, c)
    assert p.shape == (t,)
    assert p[0] == 1.0                       # p_{i,1} = 1
    assert np.all(p > 0)                     # HT requirement
    assert np.all(np.diff(p) <= 1e-7)        # monotone non-increasing
    cc = min(max(c, 1), t)
    assert np.allclose(p[:cc], 1.0)          # mandatory prefix
    assert np.isclose(p[-1], 1.0 if cc == t else 1.0 / (t - cc + 1))


@given(t=st.integers(1, 200), c=st.integers(1, 200), seed=st.integers(0, 999))
def test_rpc_mask_is_prefix_and_weights_match(t, c, seed):
    rng = np.random.default_rng(seed)
    m, w = mk.rpc_mask(rng, t, c)
    # contiguous prefix
    kept = int(m.sum())
    assert np.all(m[:kept] == 1) and np.all(m[kept:] == 0)
    assert kept >= min(max(c, 1), t)
    p = mk.rpc_survival(t, c)
    np.testing.assert_allclose(w, m / p, rtol=1e-6)


@given(t=st.integers(1, 200), seed=st.integers(0, 999),
       p=st.floats(0.05, 1.0))
def test_urs_weights(t, seed, p):
    rng = np.random.default_rng(seed)
    m, w = mk.urs_mask(rng, t, p)
    np.testing.assert_allclose(w, m / p, rtol=1e-6)
    assert set(np.unique(m)).issubset({0.0, 1.0})


def test_rpc_empirical_inclusion_matches_survival():
    """Monte-Carlo check: E[m_t] == p_t (the HT premise)."""
    t, c, n = 40, 5, 20000
    rng = np.random.default_rng(0)
    acc = np.zeros(t)
    for _ in range(n):
        m, _ = mk.rpc_mask(rng, t, c)
        acc += m
    p_hat = acc / n
    np.testing.assert_allclose(p_hat, mk.rpc_survival(t, c), atol=0.02)


def test_rpc_expected_selected_ratio():
    """E[L]/T = 1/2 + C/(2T) — the paper's Fig. 3 ~0.54-0.56 prediction."""
    t, c, n = 100, 10, 20000
    rng = np.random.default_rng(1)
    tot = sum(mk.rpc_mask(rng, t, c)[0].sum() for _ in range(n)) / n
    assert abs(tot / t - (0.5 + c / (2 * t))) < 0.01


def test_det_trunc_suffix_never_selected():
    m, w = mk.det_trunc_mask(100, 0.5)
    assert m[:50].all() and not m[50:].any()
    np.testing.assert_array_equal(m, w)


# --- stratified / poisson parity with the rust selection subsystem -------


@given(t=st.integers(1, 200), seed=st.integers(0, 999), p=st.floats(0.05, 1.0))
def test_stratified_sample_size_is_pinned(t, seed, p):
    """Kept count is floor(p*t) or ceil(p*t) — the variance-reduction
    contract the rust Stratified selector asserts too."""
    rng = np.random.default_rng(seed)
    m, w = mk.stratified_mask(rng, t, p)
    kept = int(m.sum())
    assert kept in (int(np.floor(p * t)), int(np.ceil(p * t)))
    np.testing.assert_allclose(w, m / p, rtol=1e-6)
    assert set(np.unique(m)).issubset({0.0, 1.0})


def test_stratified_marginal_inclusion_is_exactly_p():
    """MC check of the HT premise E[m_t] = p per position (parity with the
    rust test selection::stratified::marginal_inclusion_is_exactly_p)."""
    t, p, n = 30, 0.4, 20000
    rng = np.random.default_rng(2)
    acc = np.zeros(t)
    for _ in range(n):
        m, _ = mk.stratified_mask(rng, t, p)
        acc += m
    np.testing.assert_allclose(acc / n, p, atol=0.02)


def test_stratified_variance_collapses_vs_urs():
    t, p, n = 160, 0.35, 3000
    rng = np.random.default_rng(3)
    kept_u = [mk.urs_mask(rng, t, p)[0].sum() for _ in range(n)]
    kept_s = [mk.stratified_mask(rng, t, p)[0].sum() for _ in range(n)]
    assert abs(np.mean(kept_u) - p * t) < 1.0
    assert abs(np.mean(kept_s) - p * t) < 0.5
    assert np.var(kept_s) < 0.05 * np.var(kept_u)


@given(t=st.integers(1, 200), seed=st.integers(0, 999),
       k=st.floats(0.5, 64.0))
def test_poisson_weights_are_inverse_rate(t, seed, k):
    rng = np.random.default_rng(seed)
    m, w = mk.poisson_mask(rng, t, k)
    rate = min(1.0, k / t)
    np.testing.assert_allclose(w, m / rate, rtol=1e-6)
    if t <= k:  # short sequences keep everything
        assert m.all()


def test_poisson_expected_kept_is_length_aware():
    """E[kept] ≈ min(t, k) for every length — the length-aware contract."""
    k, n = 6.0, 20000
    rng = np.random.default_rng(4)
    for t in (3, 10, 40, 120):
        tot = sum(mk.poisson_mask(rng, t, k)[0].sum() for _ in range(n)) / n
        assert abs(tot - min(t, k)) < max(0.05 * min(t, k), 0.1), (t, tot)


def test_poisson_and_stratified_ht_sums_are_unbiased():
    """Σ w_t must average to t_i — the unbiasedness the rust budget
    controller relies on when it rescales rates."""
    t, n = 50, 20000
    rng = np.random.default_rng(5)
    for mask in (lambda r: mk.poisson_mask(r, t, 6.0)[1],
                 lambda r: mk.stratified_mask(r, t, 0.3)[1]):
        mean = sum(mask(rng).sum() for _ in range(n)) / n
        assert abs(mean - t) < 0.5, mean
