"""Statistical validation of Proposition 1 and Appendix B.

Monte-Carlo over mask draws: the HT-corrected masked gradient must be an
unbiased estimator of the full-token GRPO gradient for URS and RPC, while
deterministic truncation keeps a persistent bias. Also checks the URS 1/p
second-moment inflation (Sec. 3.1) and the det-trunc MSE decomposition
(App. B.5) directionally.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import masking_ref as mk
from compile import model as M
from compile.kernels import ref as kref

CFG = M.PRESETS["tiny"]
BUCKET = CFG.buckets[-1]  # mask over the full response window


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(42)
    B = 4
    S = CFG.prompt_len + BUCKET
    tokens = jnp.asarray(rng.integers(1, CFG.vocab, (B, S)), jnp.int32)
    adv = jnp.asarray(rng.normal(0, 1, B).astype(np.float32))
    lens = rng.integers(BUCKET // 2, BUCKET + 1, B)  # true response lengths
    pad = jnp.zeros((B,), jnp.int32)
    params = M.init_params(CFG, seed=7)
    logits = M.forward(CFG, params, tokens, pad)
    old_lp, _ = M._resp_logprobs(CFG, logits, tokens, BUCKET)
    old_lp = old_lp + 0.1 * jnp.asarray(
        rng.normal(0, 1, old_lp.shape).astype(np.float32))
    return params, tokens, adv, lens, pad, old_lp


def _grad_for_weights(batch, ht_w):
    params, tokens, adv, lens, pad, old_lp = batch
    inv_len = jnp.asarray((1.0 / lens).astype(np.float32))

    def loss(ps):
        logits = M.forward(CFG, ps, tokens, pad)
        new_lp, _ = M._resp_logprobs(CFG, logits, tokens, BUCKET)
        lt, _ = kref.nat_loss_tokens_ref(new_lp, old_lp, jnp.asarray(ht_w),
                                         adv, inv_len, CFG.clip_eps)
        return jnp.sum(lt)

    g = jax.grad(loss)(list(params))
    return np.concatenate([np.asarray(x).ravel() for x in g])


def _full_weights(lens):
    w = np.zeros((len(lens), BUCKET), np.float32)
    for i, t in enumerate(lens):
        w[i, :t] = 1.0
    return w


def _sampled_weights(lens, rng, scheme, **kw):
    w = np.zeros((len(lens), BUCKET), np.float32)
    for i, t in enumerate(lens):
        if scheme == "urs":
            _, wi = mk.urs_mask(rng, t, kw["p"])
        elif scheme == "rpc":
            _, wi = mk.rpc_mask(rng, t, kw["c"])
        elif scheme == "det":
            _, wi = mk.det_trunc_mask(t, kw["frac"])
        w[i, :t] = wi
    return w


@pytest.mark.parametrize("scheme,kw", [
    ("urs", {"p": 0.5}),
    ("rpc", {"c": 8}),
])
def test_ht_estimator_is_unbiased(batch, scheme, kw):
    """Averaged masked gradient converges to the full gradient; det-trunc
    (tested below) does not. 200 draws, cosine + relative-error criteria."""
    lens = batch[3]
    g_full = _grad_for_weights(batch, _full_weights(lens))
    rng = np.random.default_rng(0)
    acc = np.zeros_like(g_full)
    n = 200
    for _ in range(n):
        acc += _grad_for_weights(batch,
                                 _sampled_weights(lens, rng, scheme, **kw))
    g_hat = acc / n
    cos = float(g_hat @ g_full /
                (np.linalg.norm(g_hat) * np.linalg.norm(g_full)))
    rel = float(np.linalg.norm(g_hat - g_full) / np.linalg.norm(g_full))
    assert cos > 0.99, (scheme, cos, rel)
    assert rel < 0.2, (scheme, cos, rel)


def test_det_trunc_is_biased(batch):
    """Deterministic truncation converges to the WRONG gradient."""
    lens = batch[3]
    g_full = _grad_for_weights(batch, _full_weights(lens))
    # det-trunc is deterministic: its expectation is its single draw
    g_det = _grad_for_weights(
        batch, _sampled_weights(lens, np.random.default_rng(0), "det",
                                frac=0.5))
    rel = float(np.linalg.norm(g_det - g_full) / np.linalg.norm(g_full))
    assert rel > 0.3, rel  # persistent bias, does not vanish with averaging


def test_urs_second_moment_inflation():
    """E||g_hat||^2 = ||g||^2 / p for a single-token URS estimate."""
    rng = np.random.default_rng(3)
    g = 1.7
    for p in (0.25, 0.5):
        draws = (rng.random(200_000) < p).astype(np.float64) / p * g
        second = np.mean(draws ** 2)
        np.testing.assert_allclose(second, g * g / p, rtol=0.03)


def test_variance_ordering_urs_vs_rpc_vs_det(batch):
    """App. B: det-trunc has ~zero variance (but bias); URS/RPC have spread.

    MSE(det) must be dominated by bias^2; MSE(urs/rpc) by variance.
    """
    lens = batch[3]
    g_full = _grad_for_weights(batch, _full_weights(lens))
    rng = np.random.default_rng(1)
    n = 60

    def draws(scheme, **kw):
        return np.stack([
            _grad_for_weights(batch,
                              _sampled_weights(lens, rng, scheme, **kw))
            for _ in range(n)])

    d_urs = draws("urs", p=0.5)
    d_rpc = draws("rpc", c=8)
    d_det = np.stack([_grad_for_weights(
        batch, _sampled_weights(lens, rng, "det", frac=0.5))] * 2)

    def var(d):
        return float(np.mean(np.var(d, axis=0)))

    def bias2(d):
        return float(np.mean((d.mean(axis=0) - g_full) ** 2))

    assert var(d_det) < 1e-12
    assert var(d_urs) > var(d_det)
    assert var(d_rpc) > var(d_det)
    assert bias2(d_det) > 5 * bias2(d_urs)
    assert bias2(d_det) > 5 * bias2(d_rpc)
