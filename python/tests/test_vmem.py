"""§Perf L1 structural checks: chosen Pallas block shapes satisfy the VMEM
and tile-alignment constraints the DESIGN.md hardware-adaptation argues."""

from compile import vmem_analysis as V
from compile.kernels import attention, nat_loss


def test_nat_loss_default_blocks_fit_and_align():
    r = V.nat_loss_vmem(nat_loss.BLOCK_B, nat_loss.BLOCK_T)
    assert r["double_buffer_ok"]
    assert r["tile_aligned"]
    assert r["vmem_frac"] < 0.01  # bandwidth-bound kernel, tiny working set


def test_attention_default_blocks_fit():
    r = V.attention_vmem(attention.BLOCK_Q, attention.BLOCK_K, 256, 64)
    assert r["double_buffer_ok"]
    assert r["vmem_frac"] < 0.05
    assert r["mxu_contraction_util"] >= 0.25


def test_larger_token_tiles_still_fit():
    # the (8, 512) upgrade path discussed in DESIGN.md §8
    r = V.nat_loss_vmem(8, 512)
    assert r["double_buffer_ok"] and r["tile_aligned"]
